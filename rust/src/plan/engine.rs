//! The production half of the staged API: an [`Engine`] owns the (lazily
//! created) PJRT runtime and a multi-model registry, and materializes the
//! stage artifacts `Partitioned -> Calibrated -> Measured` exactly once
//! per model — from memory, from the on-disk cache under
//! `artifacts/cache/<model>/`, or by computing them.  Counters record how
//! many real passes ran, so callers (and tests) can verify that a full
//! tau x objective x strategy sweep costs one calibration and one
//! measurement pass.

use super::artifact::{Calibrated, Measured, Partitioned};
use super::planner::Planner;
use super::stage::{CalibSource, CalibrateStage, MeasureStage, PartitionStage, Stage};
use crate::backend::DeviceProfile;
use crate::exec::{ExecCfg, ExecPool};
use crate::graph::Graph;
use crate::model::{Manifest, ModelInfo, QLayer};
use crate::numerics::{Format, PAPER_FORMATS};
use crate::runtime::{FwdMode, ModelRuntime, Runtime};
use crate::sensitivity::Calibration;
use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Default seed of the simulator measurement pass (kept stable so cached
/// Measured artifacts are reproducible).
pub const DEFAULT_MEASURE_SEED: u64 = 0x71_4e_33;
/// Paper protocol: TTFT averaged over 5 iterations.
pub const DEFAULT_MEASURE_REPS: usize = 5;

/// Alternate executor of the Measured stage (e.g. the distributed
/// coordinator in [`crate::dist`]).  Receives the fully-assembled
/// [`MeasureStage`] and must produce an artifact bit-identical to
/// `stage.run(&pool)` — the cache layer cannot tell them apart.
pub type MeasureHook = Box<dyn FnMut(&MeasureStage<'_>) -> Result<Measured> + Send>;

/// How many real (non-cached) passes the engine has run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EngineCounters {
    /// Algorithm-2 partition computations.
    pub partition_passes: usize,
    /// Sensitivity calibration passes (PJRT fwd+bwd sweeps, or synthetic
    /// injections).
    pub calibration_passes: usize,
    /// Per-group time-gain measurement passes.
    pub measurement_passes: usize,
    /// Stage artifacts served from the on-disk cache.
    pub cache_loads: usize,
}

/// A model registered directly from in-memory pieces (tests, demos,
/// simulator-only deployments without AOT artifacts).
struct Synthetic {
    graph: Graph,
    qlayers: Vec<QLayer>,
    calibration: Calibration,
}

#[derive(Default)]
struct ModelState {
    synthetic: Option<Synthetic>,
    graph: Option<Graph>,
    partitioned: Option<Partitioned>,
    calibrated: Option<Calibrated>,
    measured: Option<Measured>,
    runtime: Option<ModelRuntime>,
}

/// Stateful artifact factory + registry.  See the module docs of
/// [`crate::plan`] for the full picture.
pub struct Engine {
    artifacts_root: Option<PathBuf>,
    manifest: Option<Manifest>,
    cache_dir: Option<PathBuf>,
    fwd_mode: FwdMode,
    device: DeviceProfile,
    /// Requested menu; planning uses its device-supported subset.
    formats: Vec<Format>,
    measure_seed: u64,
    measure_reps: usize,
    /// Worker budget for the stage fan-outs (and the planners this engine
    /// assembles).  Artifacts are bit-identical at any setting.
    exec: ExecCfg,
    rt: Option<Runtime>,
    models: BTreeMap<String, ModelState>,
    counters: EngineCounters,
    measure_hook: Option<MeasureHook>,
}

impl Engine {
    /// An empty engine (paper defaults).  Point it at AOT artifacts with
    /// [`Engine::with_artifacts_root`] and/or register synthetic models.
    pub fn new() -> Engine {
        Engine {
            artifacts_root: None,
            manifest: None,
            cache_dir: None,
            fwd_mode: FwdMode::Ref,
            device: DeviceProfile::gaudi2(),
            formats: PAPER_FORMATS.to_vec(),
            measure_seed: DEFAULT_MEASURE_SEED,
            measure_reps: DEFAULT_MEASURE_REPS,
            exec: ExecCfg::from_env(),
            rt: None,
            models: BTreeMap::new(),
            counters: EngineCounters::default(),
            measure_hook: None,
        }
    }

    /// Route every real (non-cached) Measured pass through `hook` instead
    /// of the in-process [`MeasureStage::run`].  The hook must honor the
    /// determinism contract: its artifact is cached and compared exactly
    /// like an in-process one.  Pass `None` to restore the default path.
    pub fn set_measure_hook(&mut self, hook: Option<MeasureHook>) {
        self.measure_hook = hook;
    }

    /// Directory holding manifest.json + the AOT artifacts.
    pub fn with_artifacts_root(mut self, root: impl Into<PathBuf>) -> Engine {
        self.artifacts_root = Some(root.into());
        self
    }

    /// Use an already-loaded manifest (its root becomes the artifacts root).
    pub fn with_manifest(mut self, manifest: Manifest) -> Engine {
        self.artifacts_root = Some(manifest.root.clone());
        self.manifest = Some(manifest);
        self
    }

    /// Enable the on-disk stage cache (conventionally `artifacts/cache`).
    pub fn with_cache_dir(mut self, dir: impl Into<PathBuf>) -> Engine {
        self.cache_dir = Some(dir.into());
        self
    }

    pub fn with_fwd_mode(mut self, mode: FwdMode) -> Engine {
        self.fwd_mode = mode;
        self
    }

    /// Worker budget for stage fan-outs and assembled planners.  Changing
    /// it never invalidates artifacts: parallel staging is bit-identical
    /// to sequential (the exec layer's determinism contract).
    pub fn with_exec(mut self, exec: ExecCfg) -> Engine {
        self.exec = exec;
        self
    }

    /// Shorthand for [`Engine::with_exec`] (`1` = exact sequential path).
    pub fn with_threads(self, threads: usize) -> Engine {
        self.with_exec(ExecCfg::new(threads))
    }

    pub fn exec(&self) -> ExecCfg {
        self.exec
    }

    /// The pool stage fan-outs run on.
    pub fn pool(&self) -> ExecPool {
        ExecPool::new(self.exec)
    }

    /// Drop memoized stage artifacts that depend on the device/menu or the
    /// measurement protocol.  Staging after a builder change must re-check
    /// against the NEW configuration (the disk cache enforces this; the
    /// in-memory layer must not bypass it).
    fn invalidate_stages(&mut self, partitioned: bool, measured: bool) {
        for state in self.models.values_mut() {
            if partitioned {
                state.partitioned = None;
            }
            if measured {
                state.measured = None;
            }
        }
    }

    /// Target hardware: the Measured stage simulates `device`, its cache
    /// entries are keyed by the device, and the planning format menu is
    /// restricted to the device's supported mask.
    pub fn with_device(mut self, device: DeviceProfile) -> Engine {
        if device != self.device {
            // Menu (partition artifact) and gain tables both depend on it.
            self.invalidate_stages(true, true);
        }
        self.device = device;
        self
    }

    pub fn with_formats(mut self, formats: Vec<Format>) -> Engine {
        if formats != self.formats {
            self.invalidate_stages(true, true);
        }
        self.formats = formats;
        self
    }

    /// Measurement protocol of the Measured stage (seed, TTFT reps).
    pub fn with_measure_protocol(mut self, seed: u64, reps: usize) -> Engine {
        if (seed, reps) != (self.measure_seed, self.measure_reps) {
            self.invalidate_stages(false, true);
        }
        self.measure_seed = seed;
        self.measure_reps = reps;
        self
    }

    /// Register a model from in-memory pieces: no AOT artifacts or PJRT
    /// needed; calibration is taken as given and timing runs on the
    /// simulator.
    pub fn register_synthetic(
        &mut self,
        name: &str,
        graph: Graph,
        qlayers: Vec<QLayer>,
        calibration: Calibration,
    ) {
        let state = self.models.entry(name.to_string()).or_default();
        state.synthetic = Some(Synthetic { graph, qlayers, calibration });
    }

    pub fn counters(&self) -> &EngineCounters {
        &self.counters
    }

    pub fn artifacts_root(&self) -> Option<&Path> {
        self.artifacts_root.as_deref()
    }

    pub fn device(&self) -> &DeviceProfile {
        &self.device
    }

    /// The requested format menu (see [`Engine::menu`] for the effective
    /// device-restricted one).
    pub fn formats(&self) -> &[Format] {
        &self.formats
    }

    /// The effective planning menu: the requested formats the device
    /// supports.  The BF16 baseline must survive the mask.  Also the
    /// staging-time gate rejecting structurally invalid device profiles
    /// (`with_device` is an infallible builder; in-code profiles with
    /// e.g. zero MME rates fail here, before any measurement runs).
    pub fn menu(&self) -> Result<Vec<Format>> {
        self.device.validate()?;
        let menu = self.device.restrict_menu(&self.formats);
        if !menu.contains(&Format::Bf16) {
            bail!(
                "device '{}' does not support the BF16 baseline (requested menu {:?})",
                self.device.name,
                self.formats
            );
        }
        Ok(menu)
    }

    /// Names the engine can currently serve: registered synthetic models
    /// plus (when an artifacts root is set) every manifest model.
    pub fn model_names(&mut self) -> Result<Vec<String>> {
        let mut names: Vec<String> = self.models.keys().cloned().collect();
        if self.artifacts_root.is_some() {
            let manifest = self.manifest()?;
            for m in &manifest.models {
                if !names.contains(&m.name) {
                    names.push(m.name.clone());
                }
            }
        }
        Ok(names)
    }

    fn manifest(&mut self) -> Result<&Manifest> {
        if self.manifest.is_none() {
            let root = self.artifacts_root.clone().ok_or_else(|| {
                anyhow!(
                    "engine has no artifacts root — call with_artifacts_root() \
                     or register_synthetic()"
                )
            })?;
            self.manifest = Some(Manifest::load(&root)?);
        }
        Ok(self.manifest.as_ref().unwrap())
    }

    /// Manifest metadata of a model (artifact-backed models only).
    pub fn info(&mut self, model: &str) -> Result<ModelInfo> {
        Ok(self.manifest()?.model(model)?.clone())
    }

    fn is_synthetic(&self, model: &str) -> bool {
        self.models
            .get(model)
            .map(|s| s.synthetic.is_some())
            .unwrap_or(false)
    }

    fn state_mut(&mut self, model: &str) -> &mut ModelState {
        self.models.entry(model.to_string()).or_default()
    }

    fn qlayers(&mut self, model: &str) -> Result<Vec<QLayer>> {
        if let Some(state) = self.models.get(model) {
            if let Some(sy) = &state.synthetic {
                return Ok(sy.qlayers.clone());
            }
        }
        Ok(self.info(model)?.qlayers)
    }

    /// The model's computation DAG (loaded once, then cached in memory).
    pub fn graph(&mut self, model: &str) -> Result<Graph> {
        if let Some(state) = self.models.get(model) {
            if let Some(g) = &state.graph {
                return Ok(g.clone());
            }
            if let Some(sy) = &state.synthetic {
                return Ok(sy.graph.clone());
            }
        }
        let root = self
            .artifacts_root
            .clone()
            .ok_or_else(|| anyhow!("model '{model}' is not registered and no artifacts root is set"))?;
        let info = self.info(model)?;
        let graph = info.load_graph(&root)?;
        self.state_mut(model).graph = Some(graph.clone());
        Ok(graph)
    }

    // ---- stage cache helpers --------------------------------------------

    fn cache_path(&self, model: &str, stage: &str) -> Option<PathBuf> {
        self.cache_dir
            .as_ref()
            .map(|d| d.join(model).join(format!("{stage}.json")))
    }

    fn cached_json(&self, model: &str, stage: &str) -> Option<Json> {
        let path = self.cache_path(model, stage)?;
        if !path.exists() {
            return None;
        }
        match Json::parse_file(&path) {
            Ok(j) => Some(j),
            Err(e) => {
                eprintln!(
                    "warning: ignoring unreadable cache {} ({e}); recomputing",
                    path.display()
                );
                None
            }
        }
    }

    fn store_cache(&self, model: &str, stage: &str, j: &Json) {
        if let Some(path) = self.cache_path(model, stage) {
            if let Some(parent) = path.parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            if let Err(e) = std::fs::write(&path, j.to_string()) {
                eprintln!("warning: could not write cache {}: {e}", path.display());
            }
        }
    }

    // ---- stage 1: partition ---------------------------------------------

    /// File-name tag of a non-default format menu (None for the paper
    /// menu, keeping the legacy cache file names).  Non-default menus get
    /// their own cache files — engines with different menus sharing one
    /// cache dir must not thrash each other.
    fn menu_tag(menu: &[Format]) -> Option<String> {
        if menu == &PAPER_FORMATS[..] {
            return None;
        }
        let tags: Vec<&str> = menu.iter().map(|f| f.name()).collect();
        Some(tags.join("-"))
    }

    /// Cache stage name of the Partitioned artifact (menu-keyed).
    fn partitioned_stage(menu: &[Format]) -> String {
        match Self::menu_tag(menu) {
            None => "partitioned".to_string(),
            Some(tag) => format!("partitioned-{tag}"),
        }
    }

    /// Stage-1 artifact (memory -> disk cache -> compute).
    pub fn partitioned(&mut self, model: &str) -> Result<Partitioned> {
        let mut sp = crate::obs::span("stage.partition");
        if let Some(p) = self.models.get(model).and_then(|s| s.partitioned.clone()) {
            sp.counter("cache_hit", 1.0);
            return Ok(p);
        }
        let expected_nq = self.qlayers(model)?.len();
        let menu = self.menu()?;
        let stage = Self::partitioned_stage(&menu);
        if let Some(j) = self.cached_json(model, &stage) {
            if let Ok(art) = Partitioned::from_json(&j) {
                if art.model == model && art.formats == menu && art.n_qlayers() == expected_nq {
                    self.counters.cache_loads += 1;
                    self.state_mut(model).partitioned = Some(art.clone());
                    sp.counter("cache_hit", 1.0);
                    sp.counter("disk", 1.0);
                    return Ok(art);
                }
            }
            eprintln!("warning: stale partitioned cache for '{model}'; recomputing");
        }
        let graph = self.graph(model)?;
        let qlayers = self.qlayers(model)?;
        let art =
            PartitionStage { model, graph: &graph, qlayers: &qlayers, menu: &menu }
                .run(&self.pool())?;
        self.counters.partition_passes += 1;
        sp.counter("cache_hit", 0.0);
        sp.counter("groups", art.partition.groups.len() as f64);
        self.store_cache(model, &stage, &art.to_json());
        self.state_mut(model).partitioned = Some(art.clone());
        Ok(art)
    }

    // ---- stage 2: calibration -------------------------------------------

    /// Stage-2 artifact (memory -> disk cache -> compute).  Computing runs
    /// the AOT sensitivity executable over the calibration set (PJRT) for
    /// artifact-backed models, or takes the injected calibration for
    /// synthetic ones; either counts as one calibration pass.
    pub fn calibrated(&mut self, model: &str) -> Result<Calibrated> {
        let mut sp = crate::obs::span("stage.calibrate");
        if let Some(c) = self.models.get(model).and_then(|s| s.calibrated.clone()) {
            sp.counter("cache_hit", 1.0);
            return Ok(c);
        }
        let expected_nq = self.qlayers(model)?.len();
        if let Some(j) = self.cached_json(model, "calibrated") {
            if let Ok(art) = Calibrated::from_json(&j) {
                // For synthetic models the injected calibration is ground
                // truth: the cache is only valid if it matches exactly
                // (a different injection must win over a stale file).
                let synthetic_ok = match self.models.get(model).and_then(|s| s.synthetic.as_ref())
                {
                    Some(sy) => art.calibration == sy.calibration,
                    None => true,
                };
                if art.model == model && art.calibration.s.len() == expected_nq && synthetic_ok {
                    self.counters.cache_loads += 1;
                    self.state_mut(model).calibrated = Some(art.clone());
                    sp.counter("cache_hit", 1.0);
                    sp.counter("disk", 1.0);
                    return Ok(art);
                }
            }
            eprintln!("warning: stale calibrated cache for '{model}'; recomputing");
        }
        let pool = self.pool();
        let art = if self.is_synthetic(model) {
            let state = self.models.get(model).unwrap();
            let injected = &state.synthetic.as_ref().unwrap().calibration;
            CalibrateStage { model, source: CalibSource::Injected(injected) }.run(&pool)?
        } else {
            let root = self.manifest()?.root.clone();
            let info = self.info(model)?;
            let calib_tokens = info.load_calib(&root)?;
            let mr = self.runtime(model)?;
            CalibrateStage {
                model,
                source: CalibSource::Runtime { mr, samples: &calib_tokens },
            }
            .run(&pool)?
        };
        self.counters.calibration_passes += 1;
        sp.counter("cache_hit", 0.0);
        sp.counter("qlayers", art.calibration.s.len() as f64);
        self.store_cache(model, "calibrated", &art.to_json());
        self.state_mut(model).calibrated = Some(art.clone());
        Ok(art)
    }

    // ---- stage 3: time measurement --------------------------------------

    /// Per-(device, menu) cache stage name, so measurements for different
    /// devices — or different format menus on one device — land in
    /// different files and never collide.  '+' joins the two variable
    /// parts: `fs_key` sanitizes it away from device names, so a device
    /// named like a menu tag cannot alias a (device, menu) pair.
    fn measured_stage(&self, menu: &[Format]) -> String {
        match Self::menu_tag(menu) {
            None => format!("measured-{}", self.device.fs_key()),
            Some(tag) => format!("measured-{}+{tag}", self.device.fs_key()),
        }
    }

    /// Stage-3 artifact (memory -> disk cache -> compute).  Computing runs
    /// the per-group TTFT protocol on the simulator parameterized by this
    /// engine's device profile.
    pub fn measured(&mut self, model: &str) -> Result<Measured> {
        let mut sp = crate::obs::span("stage.measure");
        if let Some(m) = self.models.get(model).and_then(|s| s.measured.clone()) {
            sp.counter("cache_hit", 1.0);
            return Ok(m);
        }
        let partitioned = self.partitioned(model)?;
        let stage = self.measured_stage(&partitioned.formats);
        if let Some(j) = self.cached_json(model, &stage) {
            if let Ok(art) = Measured::from_json(&j) {
                // The gain tables are only reusable under the SAME protocol:
                // seed, reps, and the full device profile key the
                // measurement (the file name only keys the device NAME —
                // an edited profile under the same name must still miss).
                if art.model == model
                    && art.formats == partitioned.formats
                    && art.seed == self.measure_seed
                    && art.reps == self.measure_reps
                    && art.device == self.device
                    && art.measurements.groups.len() == partitioned.partition.groups.len()
                {
                    self.counters.cache_loads += 1;
                    self.state_mut(model).measured = Some(art.clone());
                    sp.counter("cache_hit", 1.0);
                    sp.counter("disk", 1.0);
                    return Ok(art);
                }
            }
            eprintln!(
                "warning: stale measured cache for '{model}' on device '{}'; recomputing",
                self.device.name
            );
        }
        let graph = self.graph(model)?;
        let pool = self.pool();
        let ms = MeasureStage {
            model,
            graph: &graph,
            partitioned: &partitioned,
            device: &self.device,
            seed: self.measure_seed,
            reps: self.measure_reps,
        };
        let art = match self.measure_hook.as_mut() {
            Some(hook) => hook(&ms)?,
            None => ms.run(&pool)?,
        };
        self.counters.measurement_passes += 1;
        sp.counter("cache_hit", 0.0);
        sp.counter("groups", art.measurements.groups.len() as f64);
        self.store_cache(model, &stage, &art.to_json());
        self.state_mut(model).measured = Some(art.clone());
        Ok(art)
    }

    // ---- assembly --------------------------------------------------------

    /// Assemble a [`Planner`] from the three stage artifacts, materializing
    /// any that are missing.  Repeated calls re-use every artifact.  The
    /// planner inherits this engine's exec budget for its solves/sweeps.
    pub fn planner(&mut self, model: &str) -> Result<Planner> {
        let partitioned = self.partitioned(model)?;
        let calibrated = self.calibrated(model)?;
        let measured = self.measured(model)?;
        Ok(Planner::new(partitioned, calibrated, measured)?.with_exec(self.exec))
    }

    /// Stage `models` and wrap their planners in a concurrent
    /// [`crate::plan::PlanService`] (the `ampq serve` entry point).
    pub fn service(&mut self, models: &[&str]) -> Result<crate::plan::PlanService> {
        crate::plan::PlanService::from_engine(self, models)
    }

    /// The compiled PJRT runtime of an artifact-backed model (loaded once).
    /// Synthetic models have none.
    pub fn runtime(&mut self, model: &str) -> Result<&ModelRuntime> {
        if self.is_synthetic(model) {
            bail!("model '{model}' is synthetic: it has no compiled PJRT runtime");
        }
        let loaded = self
            .models
            .get(model)
            .map(|s| s.runtime.is_some())
            .unwrap_or(false);
        if !loaded {
            let root = self.manifest()?.root.clone();
            let info = self.info(model)?;
            if self.rt.is_none() {
                self.rt = Some(Runtime::new()?);
            }
            let mr = ModelRuntime::load(self.rt.as_ref().unwrap(), &root, &info, self.fwd_mode)?;
            self.state_mut(model).runtime = Some(mr);
        }
        Ok(self.models.get(model).unwrap().runtime.as_ref().unwrap())
    }
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::demo::demo_model;

    fn temp_cache(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ampq_engine_{tag}_{}", std::process::id()))
    }

    #[test]
    fn stages_run_once_and_memoize() {
        let (graph, qlayers, calibration) = demo_model(2, 3);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        let a = engine.partitioned("demo").unwrap();
        let b = engine.partitioned("demo").unwrap();
        assert_eq!(a, b);
        engine.calibrated("demo").unwrap();
        engine.measured("demo").unwrap();
        engine.planner("demo").unwrap();
        engine.planner("demo").unwrap();
        let c = engine.counters();
        assert_eq!(c.partition_passes, 1);
        assert_eq!(c.calibration_passes, 1);
        assert_eq!(c.measurement_passes, 1);
    }

    #[test]
    fn disk_cache_round_trips_between_engines() {
        let cache = temp_cache("roundtrip");
        std::fs::remove_dir_all(&cache).ok();
        let (graph, qlayers, calibration) = demo_model(2, 3);

        let mut first = Engine::new().with_cache_dir(&cache);
        first.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
        let p1 = first.planner("demo").unwrap();
        assert_eq!(first.counters().calibration_passes, 1);
        assert_eq!(first.counters().cache_loads, 0);

        // A fresh engine must serve every stage from disk — zero passes.
        let mut second = Engine::new().with_cache_dir(&cache);
        second.register_synthetic("demo", graph, qlayers, calibration);
        let p2 = second.planner("demo").unwrap();
        let c = second.counters();
        assert_eq!(c.partition_passes, 0, "partition should come from cache");
        assert_eq!(c.calibration_passes, 0, "calibration should come from cache");
        assert_eq!(c.measurement_passes, 0, "measurement should come from cache");
        assert_eq!(c.cache_loads, 3);

        // And the cached artifacts produce identical plans.
        use crate::metrics::Objective;
        use crate::plan::PlanRequest;
        let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004);
        let a = p1.solve(&req).unwrap();
        let b = p2.solve(&req).unwrap();
        assert_eq!(a, b);

        std::fs::remove_dir_all(&cache).ok();
    }

    #[test]
    fn measured_cache_is_keyed_by_device() {
        let cache = temp_cache("devkey");
        std::fs::remove_dir_all(&cache).ok();
        let (graph, qlayers, calibration) = demo_model(2, 3);

        let mut g2 = Engine::new().with_cache_dir(&cache);
        g2.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
        let m2 = g2.measured("demo").unwrap();
        assert!(cache.join("demo").join("measured-gaudi2.json").exists());

        // A gaudi3 engine over the SAME cache shares partition+calibration
        // but must re-measure: different device, different file.
        let mut g3 = Engine::new()
            .with_cache_dir(&cache)
            .with_device(DeviceProfile::gaudi3());
        g3.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
        g3.calibrated("demo").unwrap();
        let m3 = g3.measured("demo").unwrap();
        assert_eq!(g3.counters().measurement_passes, 1, "gaudi3 must re-measure");
        assert!(cache.join("demo").join("measured-gaudi3.json").exists());
        assert_eq!(m3.device.name, "gaudi3");
        // 2x MME/HBM -> a strictly faster baseline TTFT.
        assert!(m3.measurements.base_ttft < m2.measurements.base_ttft);

        // And a fresh gaudi2 engine still loads ITS artifact untouched.
        let mut again = Engine::new().with_cache_dir(&cache);
        again.register_synthetic("demo", graph, qlayers, calibration);
        let back = again.measured("demo").unwrap();
        assert_eq!(again.counters().measurement_passes, 0);
        assert_eq!(back, m2);

        std::fs::remove_dir_all(&cache).ok();
    }

    #[test]
    fn retargeting_the_device_invalidates_memoized_stages() {
        // with_device after staging must not serve another device's
        // artifacts from memory.
        let (graph, qlayers, calibration) = demo_model(1, 3);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        let m2 = engine.measured("demo").unwrap();
        assert_eq!(engine.counters().measurement_passes, 1);

        let mut engine = engine.with_device(DeviceProfile::gaudi3());
        let m3 = engine.measured("demo").unwrap();
        assert_eq!(engine.counters().measurement_passes, 2, "must re-measure");
        assert_eq!(m3.device.name, "gaudi3");
        assert!(m3.measurements.base_ttft < m2.measurements.base_ttft);

        // A no-op retarget keeps the memoized artifact.
        let mut engine = engine.with_device(DeviceProfile::gaudi3());
        engine.measured("demo").unwrap();
        assert_eq!(engine.counters().measurement_passes, 2);
    }

    #[test]
    fn device_mask_restricts_the_menu() {
        let (graph, qlayers, calibration) = demo_model(1, 3);
        let mut nofp8 = DeviceProfile::gaudi2();
        nofp8.name = "nofp8".into();
        nofp8.supported = vec![crate::numerics::Format::Bf16];
        nofp8.noise_std = 0.0; // the all-BF16 "gain" must be exactly zero
        let mut engine = Engine::new().with_device(nofp8);
        engine.register_synthetic("demo", graph, qlayers, calibration);
        assert_eq!(engine.menu().unwrap(), vec![crate::numerics::Format::Bf16]);
        let part = engine.partitioned("demo").unwrap();
        assert_eq!(part.formats, vec![crate::numerics::Format::Bf16]);
        // Every group enumerates exactly one (all-BF16) configuration.
        let m = engine.measured("demo").unwrap();
        for g in &m.measurements.groups {
            assert_eq!(g.configs.len(), 1);
            assert!(g.gains[0].abs() < 1e-9);
        }
    }

    #[test]
    fn bf16_must_survive_the_mask() {
        let (graph, qlayers, calibration) = demo_model(1, 3);
        let mut broken = DeviceProfile::gaudi2();
        broken.name = "fp8only".into();
        broken.supported = vec![crate::numerics::Format::Fp8E4m3];
        let mut engine = Engine::new().with_device(broken);
        engine.register_synthetic("demo", graph, qlayers, calibration);
        assert!(engine.partitioned("demo").is_err());
    }

    #[test]
    fn synthetic_models_have_no_runtime() {
        let (graph, qlayers, calibration) = demo_model(1, 3);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        assert!(engine.runtime("demo").is_err());
    }

    #[test]
    fn unknown_model_errors() {
        let mut engine = Engine::new();
        assert!(engine.partitioned("nope").is_err());
    }
}
