//! The production half of the staged API: an [`Engine`] owns the (lazily
//! created) PJRT runtime and a multi-model registry, and materializes the
//! stage artifacts `Partitioned -> Calibrated -> Measured` exactly once
//! per model — from memory, from the on-disk cache under
//! `artifacts/cache/<model>/`, or by computing them.  Counters record how
//! many real passes ran, so callers (and tests) can verify that a full
//! tau x objective x strategy sweep costs one calibration and one
//! measurement pass.

use super::artifact::{Calibrated, Measured, Partitioned};
use super::planner::Planner;
use crate::gaudisim::HwModel;
use crate::graph::partition::partition;
use crate::graph::Graph;
use crate::model::{Manifest, ModelInfo, QLayer};
use crate::numerics::{Format, PAPER_FORMATS};
use crate::runtime::{FwdMode, ModelRuntime, Runtime};
use crate::sensitivity::{calibrate, Calibration};
use crate::timing::{measure_groups, SimTtft};
use crate::util::{Json, Rng};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Default seed of the simulator measurement pass (kept stable so cached
/// Measured artifacts are reproducible).
pub const DEFAULT_MEASURE_SEED: u64 = 0x71_4e_33;
/// Paper protocol: TTFT averaged over 5 iterations.
pub const DEFAULT_MEASURE_REPS: usize = 5;

/// How many real (non-cached) passes the engine has run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EngineCounters {
    /// Algorithm-2 partition computations.
    pub partition_passes: usize,
    /// Sensitivity calibration passes (PJRT fwd+bwd sweeps, or synthetic
    /// injections).
    pub calibration_passes: usize,
    /// Per-group time-gain measurement passes.
    pub measurement_passes: usize,
    /// Stage artifacts served from the on-disk cache.
    pub cache_loads: usize,
}

/// Stable fingerprint of the hardware model a measurement ran under.
/// `HwModel` derives Debug over plain scalar fields, so its Debug form is
/// deterministic and captures every parameter that shapes the gain tables.
pub(crate) fn hw_digest(hw: &HwModel) -> String {
    format!("{hw:?}")
}

/// A model registered directly from in-memory pieces (tests, demos,
/// simulator-only deployments without AOT artifacts).
struct Synthetic {
    graph: Graph,
    qlayers: Vec<QLayer>,
    calibration: Calibration,
}

#[derive(Default)]
struct ModelState {
    synthetic: Option<Synthetic>,
    graph: Option<Graph>,
    partitioned: Option<Partitioned>,
    calibrated: Option<Calibrated>,
    measured: Option<Measured>,
    runtime: Option<ModelRuntime>,
}

/// Stateful artifact factory + registry.  See the module docs of
/// [`crate::plan`] for the full picture.
pub struct Engine {
    artifacts_root: Option<PathBuf>,
    manifest: Option<Manifest>,
    cache_dir: Option<PathBuf>,
    fwd_mode: FwdMode,
    hw: HwModel,
    formats: Vec<Format>,
    measure_seed: u64,
    measure_reps: usize,
    rt: Option<Runtime>,
    models: BTreeMap<String, ModelState>,
    counters: EngineCounters,
}

impl Engine {
    /// An empty engine (paper defaults).  Point it at AOT artifacts with
    /// [`Engine::with_artifacts_root`] and/or register synthetic models.
    pub fn new() -> Engine {
        Engine {
            artifacts_root: None,
            manifest: None,
            cache_dir: None,
            fwd_mode: FwdMode::Ref,
            hw: HwModel::default(),
            formats: PAPER_FORMATS.to_vec(),
            measure_seed: DEFAULT_MEASURE_SEED,
            measure_reps: DEFAULT_MEASURE_REPS,
            rt: None,
            models: BTreeMap::new(),
            counters: EngineCounters::default(),
        }
    }

    /// Directory holding manifest.json + the AOT artifacts.
    pub fn with_artifacts_root(mut self, root: impl Into<PathBuf>) -> Engine {
        self.artifacts_root = Some(root.into());
        self
    }

    /// Use an already-loaded manifest (its root becomes the artifacts root).
    pub fn with_manifest(mut self, manifest: Manifest) -> Engine {
        self.artifacts_root = Some(manifest.root.clone());
        self.manifest = Some(manifest);
        self
    }

    /// Enable the on-disk stage cache (conventionally `artifacts/cache`).
    pub fn with_cache_dir(mut self, dir: impl Into<PathBuf>) -> Engine {
        self.cache_dir = Some(dir.into());
        self
    }

    pub fn with_fwd_mode(mut self, mode: FwdMode) -> Engine {
        self.fwd_mode = mode;
        self
    }

    pub fn with_hw(mut self, hw: HwModel) -> Engine {
        self.hw = hw;
        self
    }

    pub fn with_formats(mut self, formats: Vec<Format>) -> Engine {
        self.formats = formats;
        self
    }

    /// Measurement protocol of the Measured stage (seed, TTFT reps).
    pub fn with_measure_protocol(mut self, seed: u64, reps: usize) -> Engine {
        self.measure_seed = seed;
        self.measure_reps = reps;
        self
    }

    /// Register a model from in-memory pieces: no AOT artifacts or PJRT
    /// needed; calibration is taken as given and timing runs on the
    /// simulator.
    pub fn register_synthetic(
        &mut self,
        name: &str,
        graph: Graph,
        qlayers: Vec<QLayer>,
        calibration: Calibration,
    ) {
        let state = self.models.entry(name.to_string()).or_default();
        state.synthetic = Some(Synthetic { graph, qlayers, calibration });
    }

    pub fn counters(&self) -> &EngineCounters {
        &self.counters
    }

    pub fn artifacts_root(&self) -> Option<&Path> {
        self.artifacts_root.as_deref()
    }

    pub fn hw(&self) -> &HwModel {
        &self.hw
    }

    pub fn formats(&self) -> &[Format] {
        &self.formats
    }

    /// Names the engine can currently serve: registered synthetic models
    /// plus (when an artifacts root is set) every manifest model.
    pub fn model_names(&mut self) -> Result<Vec<String>> {
        let mut names: Vec<String> = self.models.keys().cloned().collect();
        if self.artifacts_root.is_some() {
            let manifest = self.manifest()?;
            for m in &manifest.models {
                if !names.contains(&m.name) {
                    names.push(m.name.clone());
                }
            }
        }
        Ok(names)
    }

    fn manifest(&mut self) -> Result<&Manifest> {
        if self.manifest.is_none() {
            let root = self.artifacts_root.clone().ok_or_else(|| {
                anyhow!(
                    "engine has no artifacts root — call with_artifacts_root() \
                     or register_synthetic()"
                )
            })?;
            self.manifest = Some(Manifest::load(&root)?);
        }
        Ok(self.manifest.as_ref().unwrap())
    }

    /// Manifest metadata of a model (artifact-backed models only).
    pub fn info(&mut self, model: &str) -> Result<ModelInfo> {
        Ok(self.manifest()?.model(model)?.clone())
    }

    fn is_synthetic(&self, model: &str) -> bool {
        self.models
            .get(model)
            .map(|s| s.synthetic.is_some())
            .unwrap_or(false)
    }

    fn state_mut(&mut self, model: &str) -> &mut ModelState {
        self.models.entry(model.to_string()).or_default()
    }

    fn qlayers(&mut self, model: &str) -> Result<Vec<QLayer>> {
        if let Some(state) = self.models.get(model) {
            if let Some(sy) = &state.synthetic {
                return Ok(sy.qlayers.clone());
            }
        }
        Ok(self.info(model)?.qlayers)
    }

    /// The model's computation DAG (loaded once, then cached in memory).
    pub fn graph(&mut self, model: &str) -> Result<Graph> {
        if let Some(state) = self.models.get(model) {
            if let Some(g) = &state.graph {
                return Ok(g.clone());
            }
            if let Some(sy) = &state.synthetic {
                return Ok(sy.graph.clone());
            }
        }
        let root = self
            .artifacts_root
            .clone()
            .ok_or_else(|| anyhow!("model '{model}' is not registered and no artifacts root is set"))?;
        let info = self.info(model)?;
        let graph = info.load_graph(&root)?;
        self.state_mut(model).graph = Some(graph.clone());
        Ok(graph)
    }

    // ---- stage cache helpers --------------------------------------------

    fn cache_path(&self, model: &str, stage: &str) -> Option<PathBuf> {
        self.cache_dir
            .as_ref()
            .map(|d| d.join(model).join(format!("{stage}.json")))
    }

    fn cached_json(&self, model: &str, stage: &str) -> Option<Json> {
        let path = self.cache_path(model, stage)?;
        if !path.exists() {
            return None;
        }
        match Json::parse_file(&path) {
            Ok(j) => Some(j),
            Err(e) => {
                eprintln!(
                    "warning: ignoring unreadable cache {} ({e}); recomputing",
                    path.display()
                );
                None
            }
        }
    }

    fn store_cache(&self, model: &str, stage: &str, j: &Json) {
        if let Some(path) = self.cache_path(model, stage) {
            if let Some(parent) = path.parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            if let Err(e) = std::fs::write(&path, j.to_string()) {
                eprintln!("warning: could not write cache {}: {e}", path.display());
            }
        }
    }

    // ---- stage 1: partition ---------------------------------------------

    /// Stage-1 artifact (memory -> disk cache -> compute).
    pub fn partitioned(&mut self, model: &str) -> Result<Partitioned> {
        if let Some(p) = self.models.get(model).and_then(|s| s.partitioned.clone()) {
            return Ok(p);
        }
        let expected_nq = self.qlayers(model)?.len();
        if let Some(j) = self.cached_json(model, "partitioned") {
            if let Ok(art) = Partitioned::from_json(&j) {
                if art.model == model
                    && art.formats == self.formats
                    && art.n_qlayers() == expected_nq
                {
                    self.counters.cache_loads += 1;
                    self.state_mut(model).partitioned = Some(art.clone());
                    return Ok(art);
                }
            }
            eprintln!("warning: stale partitioned cache for '{model}'; recomputing");
        }
        let graph = self.graph(model)?;
        let qlayers = self.qlayers(model)?;
        let part = partition(&graph)?;
        self.counters.partition_passes += 1;
        let art = Partitioned {
            model: model.to_string(),
            formats: self.formats.clone(),
            qlayers,
            partition: part,
        };
        self.store_cache(model, "partitioned", &art.to_json());
        self.state_mut(model).partitioned = Some(art.clone());
        Ok(art)
    }

    // ---- stage 2: calibration -------------------------------------------

    /// Stage-2 artifact (memory -> disk cache -> compute).  Computing runs
    /// the AOT sensitivity executable over the calibration set (PJRT) for
    /// artifact-backed models, or takes the injected calibration for
    /// synthetic ones; either counts as one calibration pass.
    pub fn calibrated(&mut self, model: &str) -> Result<Calibrated> {
        if let Some(c) = self.models.get(model).and_then(|s| s.calibrated.clone()) {
            return Ok(c);
        }
        let expected_nq = self.qlayers(model)?.len();
        if let Some(j) = self.cached_json(model, "calibrated") {
            if let Ok(art) = Calibrated::from_json(&j) {
                // For synthetic models the injected calibration is ground
                // truth: the cache is only valid if it matches exactly
                // (a different injection must win over a stale file).
                let synthetic_ok = match self.models.get(model).and_then(|s| s.synthetic.as_ref())
                {
                    Some(sy) => art.calibration == sy.calibration,
                    None => true,
                };
                if art.model == model && art.calibration.s.len() == expected_nq && synthetic_ok {
                    self.counters.cache_loads += 1;
                    self.state_mut(model).calibrated = Some(art.clone());
                    return Ok(art);
                }
            }
            eprintln!("warning: stale calibrated cache for '{model}'; recomputing");
        }
        let calibration = if self.is_synthetic(model) {
            let state = self.models.get(model).unwrap();
            state.synthetic.as_ref().unwrap().calibration.clone()
        } else {
            let root = self.manifest()?.root.clone();
            let info = self.info(model)?;
            let calib_tokens = info.load_calib(&root)?;
            let mr = self.runtime(model)?;
            calibrate(mr, &calib_tokens)?
        };
        self.counters.calibration_passes += 1;
        let art = Calibrated { model: model.to_string(), calibration };
        self.store_cache(model, "calibrated", &art.to_json());
        self.state_mut(model).calibrated = Some(art.clone());
        Ok(art)
    }

    // ---- stage 3: time measurement --------------------------------------

    /// Stage-3 artifact (memory -> disk cache -> compute).  Computing runs
    /// the per-group TTFT protocol on the Gaudi-2-like simulator.
    pub fn measured(&mut self, model: &str) -> Result<Measured> {
        if let Some(m) = self.models.get(model).and_then(|s| s.measured.clone()) {
            return Ok(m);
        }
        let partitioned = self.partitioned(model)?;
        let hw_digest = hw_digest(&self.hw);
        if let Some(j) = self.cached_json(model, "measured") {
            if let Ok(art) = Measured::from_json(&j) {
                // The gain tables are only reusable under the SAME protocol:
                // seed, reps, and hardware model all key the measurement.
                if art.model == model
                    && art.formats == self.formats
                    && art.seed == self.measure_seed
                    && art.reps == self.measure_reps
                    && art.hw_digest == hw_digest
                    && art.measurements.groups.len() == partitioned.partition.groups.len()
                {
                    self.counters.cache_loads += 1;
                    self.state_mut(model).measured = Some(art.clone());
                    return Ok(art);
                }
            }
            eprintln!("warning: stale measured cache for '{model}'; recomputing");
        }
        let graph = self.graph(model)?;
        let sim = crate::gaudisim::Simulator::new(&graph, self.hw.clone());
        let mut src = SimTtft {
            sim,
            rng: Rng::new(self.measure_seed),
            reps: self.measure_reps,
        };
        let tm = measure_groups(&mut src, &partitioned.partition, &self.formats)?;
        self.counters.measurement_passes += 1;
        let art = Measured {
            model: model.to_string(),
            formats: self.formats.clone(),
            seed: self.measure_seed,
            reps: self.measure_reps,
            hw_digest,
            measurements: tm,
        };
        self.store_cache(model, "measured", &art.to_json());
        self.state_mut(model).measured = Some(art.clone());
        Ok(art)
    }

    // ---- assembly --------------------------------------------------------

    /// Assemble a [`Planner`] from the three stage artifacts, materializing
    /// any that are missing.  Repeated calls re-use every artifact.
    pub fn planner(&mut self, model: &str) -> Result<Planner> {
        let partitioned = self.partitioned(model)?;
        let calibrated = self.calibrated(model)?;
        let measured = self.measured(model)?;
        Planner::new(partitioned, calibrated, measured)
    }

    /// Stage `models` and wrap their planners in a concurrent
    /// [`crate::plan::PlanService`] (the `ampq serve` entry point).
    pub fn service(&mut self, models: &[&str]) -> Result<crate::plan::PlanService> {
        crate::plan::PlanService::from_engine(self, models)
    }

    /// The compiled PJRT runtime of an artifact-backed model (loaded once).
    /// Synthetic models have none.
    pub fn runtime(&mut self, model: &str) -> Result<&ModelRuntime> {
        if self.is_synthetic(model) {
            bail!("model '{model}' is synthetic: it has no compiled PJRT runtime");
        }
        let loaded = self
            .models
            .get(model)
            .map(|s| s.runtime.is_some())
            .unwrap_or(false);
        if !loaded {
            let root = self.manifest()?.root.clone();
            let info = self.info(model)?;
            if self.rt.is_none() {
                self.rt = Some(Runtime::new()?);
            }
            let mr = ModelRuntime::load(self.rt.as_ref().unwrap(), &root, &info, self.fwd_mode)?;
            self.state_mut(model).runtime = Some(mr);
        }
        Ok(self.models.get(model).unwrap().runtime.as_ref().unwrap())
    }
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::demo::demo_model;

    fn temp_cache(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ampq_engine_{tag}_{}", std::process::id()))
    }

    #[test]
    fn stages_run_once_and_memoize() {
        let (graph, qlayers, calibration) = demo_model(2, 3);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        let a = engine.partitioned("demo").unwrap();
        let b = engine.partitioned("demo").unwrap();
        assert_eq!(a, b);
        engine.calibrated("demo").unwrap();
        engine.measured("demo").unwrap();
        engine.planner("demo").unwrap();
        engine.planner("demo").unwrap();
        let c = engine.counters();
        assert_eq!(c.partition_passes, 1);
        assert_eq!(c.calibration_passes, 1);
        assert_eq!(c.measurement_passes, 1);
    }

    #[test]
    fn disk_cache_round_trips_between_engines() {
        let cache = temp_cache("roundtrip");
        std::fs::remove_dir_all(&cache).ok();
        let (graph, qlayers, calibration) = demo_model(2, 3);

        let mut first = Engine::new().with_cache_dir(&cache);
        first.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
        let p1 = first.planner("demo").unwrap();
        assert_eq!(first.counters().calibration_passes, 1);
        assert_eq!(first.counters().cache_loads, 0);

        // A fresh engine must serve every stage from disk — zero passes.
        let mut second = Engine::new().with_cache_dir(&cache);
        second.register_synthetic("demo", graph, qlayers, calibration);
        let p2 = second.planner("demo").unwrap();
        let c = second.counters();
        assert_eq!(c.partition_passes, 0, "partition should come from cache");
        assert_eq!(c.calibration_passes, 0, "calibration should come from cache");
        assert_eq!(c.measurement_passes, 0, "measurement should come from cache");
        assert_eq!(c.cache_loads, 3);

        // And the cached artifacts produce identical plans.
        use crate::metrics::Objective;
        use crate::plan::PlanRequest;
        let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004);
        let a = p1.solve(&req).unwrap();
        let b = p2.solve(&req).unwrap();
        assert_eq!(a, b);

        std::fs::remove_dir_all(&cache).ok();
    }

    #[test]
    fn synthetic_models_have_no_runtime() {
        let (graph, qlayers, calibration) = demo_model(1, 3);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        assert!(engine.runtime("demo").is_err());
    }

    #[test]
    fn unknown_model_errors() {
        let mut engine = Engine::new();
        assert!(engine.partitioned("nope").is_err());
    }
}
