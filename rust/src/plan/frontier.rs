//! Precomputed Pareto frontiers over the tau -> gain tradeoff.
//!
//! A pointwise IP solve answers ONE budget; serving wants the whole curve.
//! Two builders produce it:
//!
//! * [`build`] assembles a frontier from pre-solved (mse, gain, config)
//!   records — the parametric one-pass path (`Planner::frontier` for the
//!   IP strategy feeds it `solver::parametric`'s chain-DP curve, computed
//!   in a single sweep instead of one IP solve per knot; warm re-solves
//!   reuse the planner's committed `FrontierDp` arena, see
//!   `Planner::frontier_delta`);
//! * [`sweep`] runs a pointwise solver over the calibration's tau range
//!   (paper grid + an even cover of [0, tau_max]) and bisects adjacent
//!   taus whose optimal gains differ to localize the breakpoints — the
//!   pre-parametric path, kept for the closed-form baseline strategies and
//!   as the property-test oracle.
//!
//! Both Pareto-filter their records into points with strictly increasing
//! predicted MSE and gain.  [`Frontier::at`] then answers any tau in
//! O(log n): the optimal gain is a step function of the budget, so the
//! highest-gain point whose MSE fits IS the pointwise optimum (asserted
//! against fresh IP solves in tests).  Frontiers round-trip through JSON,
//! so they can be precomputed offline and shipped to serving hosts.
//!
//! All float sorts here are TOTAL (`f64::total_cmp`): a NaN smuggled in by
//! a caller can produce a rejected artifact, never a panic.  NaN/negative
//! taus themselves are rejected at the `PlanRequest`/CLI boundary.

use super::artifact::{check_header, formats_from_json, formats_to_json, num, SCHEMA_VERSION};
use crate::coordinator::Strategy;
use crate::exec::ExecPool;
use crate::gaudisim::MpConfig;
use crate::metrics::Objective;
use crate::solver::EPS;
use crate::util::Json;
use anyhow::{anyhow, bail, Result};

/// One Pareto point: the best configuration at its MSE level.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontierPoint {
    /// Smallest swept tau whose solve produced this plan.
    pub tau: f64,
    /// Predicted loss MSE d of `config` (eq. 6).
    pub predicted_mse: f64,
    /// Objective-family gain of `config`.
    pub gain: f64,
    pub config: MpConfig,
}

/// A precomputed, JSON-round-trippable Pareto frontier for one
/// (model, objective, strategy).
#[derive(Clone, Debug, PartialEq)]
pub struct Frontier {
    pub model: String,
    pub objective: Objective,
    pub strategy: Strategy,
    /// E[g^2] mapping tau -> budget (tau^2 * E[g^2]).
    pub eg2: f64,
    /// Upper end of the swept tau range (every configuration fits beyond).
    pub tau_max: f64,
    /// Pareto points, strictly increasing in BOTH predicted_mse and gain.
    pub points: Vec<FrontierPoint>,
}

impl Frontier {
    /// O(log n) lookup: the highest-gain point whose predicted loss MSE
    /// fits the tau budget.  Below the first point (the paper's tau = 0
    /// edge) the all-baseline fallback point itself is returned — exactly
    /// what a pointwise infeasible solve falls back to.  Total for every
    /// float input: a NaN tau compares below every point and resolves to
    /// the fallback (serving layers reject NaN taus before they get here).
    pub fn at(&self, tau: f64) -> &FrontierPoint {
        let budget = tau * tau * self.eg2;
        let k = self.points.partition_point(|p| p.predicted_mse <= budget + EPS);
        if k == 0 {
            &self.points[0]
        } else {
            &self.points[k - 1]
        }
    }

    /// Whether the point `at(tau)` actually fits the tau budget.
    pub fn feasible_at(&self, tau: f64) -> bool {
        self.at(tau).predicted_mse <= tau * tau * self.eg2 + EPS
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn to_json(&self) -> Json {
        let points = self
            .points
            .iter()
            .map(|p| {
                Json::Obj(vec![
                    ("tau".into(), num(p.tau)),
                    ("predicted_mse".into(), num(p.predicted_mse)),
                    ("gain".into(), num(p.gain)),
                    ("config".into(), formats_to_json(&p.config.0)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("schema".into(), Json::Num(SCHEMA_VERSION as f64)),
            ("kind".into(), Json::Str("frontier".into())),
            ("model".into(), Json::Str(self.model.clone())),
            ("objective".into(), Json::Str(self.objective.key().into())),
            ("strategy".into(), Json::Str(self.strategy.key().into())),
            ("eg2".into(), num(self.eg2)),
            ("tau_max".into(), num(self.tau_max)),
            ("points".into(), Json::Arr(points)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Frontier> {
        check_header(j, "frontier")?;
        let okey = j.get("objective")?.str()?;
        let objective =
            Objective::from_key(okey).ok_or_else(|| anyhow!("unknown objective '{okey}'"))?;
        let skey = j.get("strategy")?.str()?;
        let strategy =
            Strategy::from_key(skey).ok_or_else(|| anyhow!("unknown strategy '{skey}'"))?;
        let points = j
            .get("points")?
            .arr()?
            .iter()
            .map(|pj| {
                Ok(FrontierPoint {
                    tau: pj.get("tau")?.f64()?,
                    predicted_mse: pj.get("predicted_mse")?.f64()?,
                    gain: pj.get("gain")?.f64()?,
                    config: MpConfig(formats_from_json(pj.get("config")?)?),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if points.is_empty() {
            bail!("frontier has no points");
        }
        // `at` binary-searches over predicted_mse — reject artifacts whose
        // points were reordered or merged out of the Pareto invariant.
        for (i, w) in points.windows(2).enumerate() {
            if !(w[1].predicted_mse > w[0].predicted_mse && w[1].gain > w[0].gain) {
                bail!(
                    "frontier points must strictly increase in predicted_mse and gain \
                     (violated between points {i} and {})",
                    i + 1
                );
            }
        }
        Ok(Frontier {
            model: j.get("model")?.str()?.to_string(),
            objective,
            strategy,
            eg2: j.get("eg2")?.f64()?,
            tau_max: j.get("tau_max")?.f64()?,
            points,
        })
    }
}

/// Cap on total pointwise solves per sweep (grid + bisection refinement).
const MAX_REFINE_SOLVES: usize = 320;

/// Sweep taus through `solve` (tau -> (predicted_mse, gain, config)),
/// refine gain breakpoints by bisection, Pareto-filter, and assemble the
/// [`Frontier`].  `grid` taus outside [0, tau_max] are clamped away; 0 and
/// tau_max themselves are always solved.
///
/// Solves are batched across `pool`: the initial grid in one batch, then
/// one batch of midpoints per bisection round.  Each round's batch is a
/// pure function of the previous round's (ordered) results — never of the
/// thread count — so the swept frontier is bit-identical at any
/// parallelism, including how the solve budget truncates refinement.
pub fn sweep<F>(
    model: &str,
    objective: Objective,
    strategy: Strategy,
    eg2: f64,
    tau_max: f64,
    grid: &[f64],
    pool: &ExecPool,
    solve: F,
) -> Result<Frontier>
where
    F: Fn(f64) -> Result<(f64, f64, MpConfig)> + Sync,
{
    struct Rec {
        tau: f64,
        mse: f64,
        gain: f64,
        config: MpConfig,
    }
    if !(tau_max > 0.0) || !tau_max.is_finite() {
        bail!("tau_max must be positive and finite (got {tau_max})");
    }
    let mut taus: Vec<f64> = grid
        .iter()
        .copied()
        .filter(|t| t.is_finite() && *t >= 0.0 && *t <= tau_max)
        .collect();
    taus.push(0.0);
    taus.push(tau_max);
    taus.sort_by(f64::total_cmp);
    taus.dedup_by(|a, b| (*a - *b).abs() <= tau_max * 1e-9);

    let batch = |ts: &[f64]| -> Result<Vec<Rec>> {
        let solved: Vec<(f64, f64, MpConfig)> =
            pool.try_par_map(ts.len(), |i| solve(ts[i]))?;
        Ok(ts
            .iter()
            .zip(solved)
            .map(|(&tau, (mse, gain, config))| Rec { tau, mse, gain, config })
            .collect())
    };
    let mut records: Vec<Rec> = batch(&taus)?;

    // Bisect adjacent taus with differing optimal gains until the gain step
    // is localized to tau_res (or the solve budget runs out) — one batched
    // round of midpoints per iteration, intervals kept in ascending order.
    let gain_span = records.iter().map(|r| r.gain.abs()).fold(0.0, f64::max);
    let gtol = 1e-9 * (1.0 + gain_span);
    let tau_res = tau_max * 1e-4;
    let mut intervals: Vec<(f64, f64, f64, f64)> = records
        .windows(2)
        .filter(|w| (w[1].gain - w[0].gain).abs() > gtol && w[1].tau - w[0].tau > tau_res)
        .map(|w| (w[0].tau, w[0].gain, w[1].tau, w[1].gain))
        .collect();
    let mut solves_left = MAX_REFINE_SOLVES;
    while !intervals.is_empty() && solves_left > 0 {
        // Deterministic truncation: the budget cuts the round's tail, not
        // whatever a thread happened to pop last.
        intervals.truncate(solves_left);
        let mids: Vec<f64> = intervals.iter().map(|(lo, _, hi, _)| 0.5 * (lo + hi)).collect();
        solves_left -= mids.len();
        let solved = batch(&mids)?;
        let mut next: Vec<(f64, f64, f64, f64)> = Vec::new();
        for ((lo, glo, hi, ghi), rec) in intervals.into_iter().zip(&solved) {
            let mid = rec.tau;
            if (rec.gain - glo).abs() > gtol && mid - lo > tau_res {
                next.push((lo, glo, mid, rec.gain));
            }
            if (ghi - rec.gain).abs() > gtol && hi - mid > tau_res {
                next.push((mid, rec.gain, hi, ghi));
            }
        }
        records.extend(solved);
        intervals = next;
    }

    // Pareto filter: ascending MSE, keep only strictly increasing gain
    // (ties resolve to the cheapest MSE, then the smallest tau; the sort
    // is total so malformed solver output cannot panic the sweep).
    records.sort_by(|a, b| {
        a.mse
            .total_cmp(&b.mse)
            .then(b.gain.total_cmp(&a.gain))
            .then(a.tau.total_cmp(&b.tau))
    });
    let mut points: Vec<FrontierPoint> = Vec::new();
    for r in records {
        let keep = points.last().map_or(true, |l| r.gain > l.gain);
        if keep {
            points.push(FrontierPoint {
                tau: r.tau,
                predicted_mse: r.mse,
                gain: r.gain,
                config: r.config,
            });
        }
    }
    if points.is_empty() {
        bail!("frontier sweep produced no points");
    }
    Ok(Frontier { model: model.to_string(), objective, strategy, eg2, tau_max, points })
}

/// Assemble a [`Frontier`] from pre-solved `(predicted_mse, gain, config)`
/// records — the parametric one-pass path.  Records are Pareto-filtered
/// exactly like [`sweep`]'s (ascending MSE, strictly increasing gain, ties
/// to the cheapest MSE); non-finite records are dropped rather than
/// panicking a sort.  Knot taus are closed-form: `sqrt(mse / eg2)` is the
/// smallest threshold whose budget admits the knot — except the first
/// point, which keeps `tau = 0`: it is the fallback every infeasible
/// budget resolves to, matching the bisection sweep's tau-0 record
/// bit-for-bit.
pub fn build(
    model: &str,
    objective: Objective,
    strategy: Strategy,
    eg2: f64,
    tau_max: f64,
    mut records: Vec<(f64, f64, MpConfig)>,
) -> Result<Frontier> {
    if !(tau_max > 0.0) || !tau_max.is_finite() {
        bail!("tau_max must be positive and finite (got {tau_max})");
    }
    if !(eg2 > 0.0) || !eg2.is_finite() {
        bail!("eg2 must be positive and finite (got {eg2})");
    }
    records.retain(|(mse, gain, _)| mse.is_finite() && gain.is_finite());
    records.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1)));
    let mut points: Vec<FrontierPoint> = Vec::new();
    for (mse, gain, config) in records {
        if points.last().map_or(true, |l| gain > l.gain) {
            let tau = if points.is_empty() { 0.0 } else { (mse / eg2).sqrt().min(tau_max) };
            points.push(FrontierPoint { tau, predicted_mse: mse, gain, config });
        }
    }
    if points.is_empty() {
        bail!("frontier build produced no points");
    }
    Ok(Frontier { model: model.to_string(), objective, strategy, eg2, tau_max, points })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::Format;

    /// Synthetic 1-knob "solver": gain jumps 0 -> 5 -> 9 at known budgets.
    fn step_solve(tau: f64) -> Result<(f64, f64, MpConfig)> {
        let budget = tau * tau; // eg2 = 1
        if budget >= 0.9 {
            Ok((0.9, 9.0, MpConfig(vec![Format::Fp8E4m3, Format::Fp8E4m3])))
        } else if budget >= 0.25 {
            Ok((0.25, 5.0, MpConfig(vec![Format::Fp8E4m3, Format::Bf16])))
        } else {
            Ok((0.01, 0.0, MpConfig(vec![Format::Bf16, Format::Bf16])))
        }
    }

    fn step_frontier() -> Frontier {
        sweep(
            "m",
            Objective::EmpiricalTime,
            Strategy::Ip,
            1.0,
            2.0,
            &[0.0, 0.1, 1.2, 2.0],
            &ExecPool::sequential(),
            step_solve,
        )
        .unwrap()
    }

    #[test]
    fn sweep_is_bit_identical_across_thread_counts() {
        use crate::exec::ExecCfg;
        let par = sweep(
            "m",
            Objective::EmpiricalTime,
            Strategy::Ip,
            1.0,
            2.0,
            &[0.0, 0.1, 1.2, 2.0],
            &ExecPool::new(ExecCfg::new(8)),
            step_solve,
        )
        .unwrap();
        assert_eq!(par, step_frontier());
    }

    #[test]
    fn sweep_finds_every_step() {
        let f = step_frontier();
        assert_eq!(f.points.len(), 3);
        assert_eq!(f.points[0].gain, 0.0);
        assert_eq!(f.points[1].gain, 5.0);
        assert_eq!(f.points[2].gain, 9.0);
        // Strictly increasing in both coordinates.
        for w in f.points.windows(2) {
            assert!(w[1].predicted_mse > w[0].predicted_mse);
            assert!(w[1].gain > w[0].gain);
        }
    }

    #[test]
    fn at_matches_the_step_function() {
        let f = step_frontier();
        for tau in [0.0, 0.05, 0.3, 0.49, 0.51, 0.7, 0.94, 0.96, 1.5, 2.0] {
            let (mse, gain, config) = step_solve(tau).unwrap();
            let p = f.at(tau);
            assert_eq!(p.gain, gain, "tau {tau}");
            assert_eq!(p.predicted_mse, mse, "tau {tau}");
            assert_eq!(p.config, config, "tau {tau}");
        }
        // Below the fallback point's own MSE, at() still returns it.
        assert_eq!(f.at(0.0).gain, 0.0);
        assert!(!f.feasible_at(0.0));
        assert!(f.feasible_at(0.2));
    }

    #[test]
    fn json_roundtrip_exact() {
        let f = step_frontier();
        let text = f.to_json().to_string();
        let back = Frontier::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn rejects_other_kinds() {
        let f = step_frontier();
        let mut j = f.to_json();
        if let Json::Obj(kv) = &mut j {
            kv[1].1 = Json::Str("plan".into());
        }
        assert!(Frontier::from_json(&j).is_err());
    }

    #[test]
    fn rejects_unsorted_points() {
        let f = step_frontier();
        let mut j = f.to_json();
        if let Json::Obj(kv) = &mut j {
            let points = kv.iter_mut().find(|(k, _)| k == "points").unwrap();
            if let Json::Arr(pts) = &mut points.1 {
                pts.swap(0, 2); // break the sorted invariant at() relies on
            }
        }
        assert!(Frontier::from_json(&j).is_err());
    }

    #[test]
    fn build_matches_sweep_pareto_semantics() {
        // Records in arbitrary order, with a dominated and a non-finite
        // entry: build keeps the Pareto set with closed-form knot taus.
        let cfg = |fs: &[Format]| MpConfig(fs.to_vec());
        let records = vec![
            (0.25, 5.0, cfg(&[Format::Fp8E4m3, Format::Bf16])),
            (0.01, 0.0, cfg(&[Format::Bf16, Format::Bf16])),
            (0.9, 9.0, cfg(&[Format::Fp8E4m3, Format::Fp8E4m3])),
            (0.3, 4.0, cfg(&[Format::Bf16, Format::Fp8E4m3])), // dominated
            (f64::NAN, 99.0, cfg(&[Format::Bf16, Format::Bf16])), // dropped
        ];
        let f = build("m", Objective::EmpiricalTime, Strategy::Ip, 1.0, 2.0, records).unwrap();
        assert_eq!(f.points.len(), 3);
        assert_eq!(f.points[0].tau, 0.0);
        assert!((f.points[1].tau - 0.5).abs() < 1e-12); // sqrt(0.25 / 1)
        assert!((f.points[2].tau - 0.9f64.sqrt()).abs() < 1e-12);
        // at() agrees with the step function the records encode.
        assert_eq!(f.at(0.3).gain, 0.0);
        assert_eq!(f.at(0.5).gain, 5.0);
        assert_eq!(f.at(1.0).gain, 9.0);
        // Round-trips like any swept frontier.
        let back = Frontier::from_json(&Json::parse(&f.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, f);
        // Degenerate parameters are rejected, not propagated.
        assert!(build("m", Objective::EmpiricalTime, Strategy::Ip, 0.0, 2.0, vec![]).is_err());
        assert!(build("m", Objective::EmpiricalTime, Strategy::Ip, 1.0, f64::NAN, vec![]).is_err());
    }

    #[test]
    fn rejects_bad_tau_max() {
        assert!(sweep(
            "m",
            Objective::EmpiricalTime,
            Strategy::Ip,
            1.0,
            0.0,
            &[],
            &ExecPool::sequential(),
            step_solve
        )
        .is_err());
    }
}
