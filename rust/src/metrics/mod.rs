//! Performance-metric builders: the c_{j,p} vectors the IP maximizes
//! (paper §2.3).  Three objectives:
//!   * empirical time  c^ET — measured per-group TTFT gains (§2.3.1),
//!   * theoretical time c^TT — MAC-count model, additive per layer (eq. 24),
//!   * memory          c^M  — weight-byte reduction, linear layers only,
//!     singleton groups (eq. 25-26).

use crate::backend::DeviceProfile;
use crate::gaudisim::{enumerate_configs, MpConfig};
use crate::graph::partition::Partition;
use crate::model::{LayerKind, QLayer};
use crate::numerics::{delta_m, Format};
use crate::timing::TimeMeasurements;

/// Objective selector (strategy families IP-ET / IP-TT / IP-M).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    EmpiricalTime,
    TheoreticalTime,
    Memory,
}

impl Objective {
    /// Every objective family, in the paper's presentation order.
    pub const ALL: [Objective; 3] =
        [Objective::EmpiricalTime, Objective::TheoreticalTime, Objective::Memory];

    pub fn name(self) -> &'static str {
        match self {
            Objective::EmpiricalTime => "IP-ET",
            Objective::TheoreticalTime => "IP-TT",
            Objective::Memory => "IP-M",
        }
    }

    /// Short machine-readable key (CLI flags, Plan serialization).
    pub fn key(self) -> &'static str {
        match self {
            Objective::EmpiricalTime => "et",
            Objective::TheoreticalTime => "tt",
            Objective::Memory => "m",
        }
    }

    pub fn from_key(s: &str) -> Option<Objective> {
        Some(match s {
            "et" => Objective::EmpiricalTime,
            "tt" => Objective::TheoreticalTime,
            "m" => Objective::Memory,
            _ => return None,
        })
    }
}

/// One IP group: candidate configurations (paper's Q_j columns) and their
/// performance-gain values c_{j,p}.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupChoices {
    pub qidxs: Vec<usize>,
    pub configs: Vec<Vec<Format>>,
    pub gains: Vec<f64>,
}

/// c^ET: straight from the measured per-group tables.
pub fn empirical_groups(tm: &TimeMeasurements) -> Vec<GroupChoices> {
    tm.groups
        .iter()
        .map(|g| GroupChoices {
            qidxs: g.qidxs.clone(),
            configs: g.configs.clone(),
            gains: g.gains.clone(),
        })
        .collect()
}

/// Per-layer theoretical gain c^TT_{l,f} = MACs_l * delta_T,f (eq. 24),
/// in units of "BF16 MAC times" (the IP is scale-invariant).  delta_T,f
/// comes from the device's MME rate table — it is hardware data.
pub fn tt_layer_gain(q: &QLayer, f: Format, device: &DeviceProfile) -> f64 {
    q.macs as f64 * device.delta_t(f)
}

/// c^TT grouped on the same partition as ET (additivity makes this exact).
pub fn theoretical_groups(
    part: &Partition,
    qlayers: &[QLayer],
    formats: &[Format],
    device: &DeviceProfile,
) -> Vec<GroupChoices> {
    part.groups
        .iter()
        .map(|g| {
            let configs = enumerate_configs(formats, g.qidxs.len());
            let gains = configs
                .iter()
                .map(|cfg| {
                    g.qidxs
                        .iter()
                        .zip(cfg)
                        .map(|(&q, &f)| tt_layer_gain(&qlayers[q], f, device))
                        .sum()
                })
                .collect();
            GroupChoices { qidxs: g.qidxs.clone(), configs, gains }
        })
        .collect()
}

/// Per-layer memory gain c^M_{l,f} = params_l * delta_M(f) bytes (eq. 25);
/// zero for BGEMM (intermediates are stack-allocated — paper §2.3.3).
pub fn mem_layer_gain(q: &QLayer, f: Format) -> f64 {
    match q.kind {
        LayerKind::Linear => q.params as f64 * delta_m(f),
        LayerKind::Bgemm => 0.0,
    }
}

/// c^M: singleton groups over LINEAR layers only (paper: "IP-M quantizes
/// only linear layers"); BGEMM layers are left out of the IP entirely and
/// stay at the baseline format.
pub fn memory_groups(qlayers: &[QLayer], formats: &[Format]) -> Vec<GroupChoices> {
    qlayers
        .iter()
        .enumerate()
        .filter(|(_, q)| q.kind == LayerKind::Linear)
        .map(|(l, q)| {
            let configs = enumerate_configs(formats, 1);
            let gains = configs.iter().map(|cfg| mem_layer_gain(q, cfg[0])).collect();
            GroupChoices { qidxs: vec![l], configs, gains }
        })
        .collect()
}

/// Total stored weight bytes of a full configuration: every layer's params
/// at that layer's format width (BGEMM layers hold no weights — params is
/// zero).  The cost table of memory-capped PlanRequests.
pub fn weight_bytes(qlayers: &[QLayer], cfg: &MpConfig) -> f64 {
    qlayers
        .iter()
        .enumerate()
        .map(|(l, q)| q.params as f64 * cfg.get(l).bytes() as f64)
        .sum()
}

/// Weight bytes of one group's layers under one group configuration
/// (a column of the memory cost dimension).
pub fn group_weight_bytes(qlayers: &[QLayer], qidxs: &[usize], cfg: &[Format]) -> f64 {
    qidxs
        .iter()
        .zip(cfg)
        .map(|(&q, &f)| qlayers[q].params as f64 * f.bytes() as f64)
        .sum()
}

/// Layers covered by a set of groups (everything else defaults to BF16).
pub fn covered_layers(groups: &[GroupChoices], n_qlayers: usize) -> Vec<bool> {
    let mut covered = vec![false; n_qlayers];
    for g in groups {
        for &q in &g.qidxs {
            covered[q] = true;
        }
    }
    covered
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition::partition;
    use crate::graph::testutil::diamond;
    use crate::numerics::PAPER_FORMATS;

    fn qlayers3() -> Vec<QLayer> {
        vec![
            QLayer { name: "x".into(), kind: LayerKind::Linear, c: 8, k: 8, macs: 1000, params: 64 },
            QLayer { name: "y".into(), kind: LayerKind::Bgemm, c: 8, k: 8, macs: 500, params: 0 },
            QLayer { name: "m".into(), kind: LayerKind::Linear, c: 8, k: 8, macs: 2000, params: 128 },
        ]
    }

    #[test]
    fn tt_gains_additive_and_scaled() {
        let g = diamond();
        let part = partition(&g).unwrap();
        let groups =
            theoretical_groups(&part, &qlayers3(), &PAPER_FORMATS, &DeviceProfile::gaudi2());
        assert_eq!(groups.len(), 1);
        let gc = &groups[0];
        // All-BF16 gain = 0; all-FP8 = 0.5 * total MACs.
        let bf16 = gc.configs.iter().position(|c| c.iter().all(|f| *f == Format::Bf16)).unwrap();
        let fp8 = gc.configs.iter().position(|c| c.iter().all(|f| *f == Format::Fp8E4m3)).unwrap();
        assert_eq!(gc.gains[bf16], 0.0);
        assert!((gc.gains[fp8] - 0.5 * 3500.0).abs() < 1e-9);
    }

    #[test]
    fn tt_gains_are_device_dependent() {
        let qs = qlayers3();
        let gaudi = DeviceProfile::gaudi2();
        let cpu = DeviceProfile::cpu_roofline();
        assert!(tt_layer_gain(&qs[0], Format::Fp8E4m3, &gaudi) > 0.0);
        // No fp8 throughput advantage -> zero theoretical time gain.
        assert_eq!(tt_layer_gain(&qs[0], Format::Fp8E4m3, &cpu), 0.0);
    }

    #[test]
    fn memory_skips_bgemm() {
        let groups = memory_groups(&qlayers3(), &PAPER_FORMATS);
        assert_eq!(groups.len(), 2); // only the two linear layers
        for g in &groups {
            assert_eq!(g.qidxs.len(), 1);
            assert_eq!(g.configs.len(), 2);
            // FP8 gain = params * 1 byte.
            let fp8 = g.configs.iter().position(|c| c[0] == Format::Fp8E4m3).unwrap();
            assert!(g.gains[fp8] > 0.0);
        }
        let covered = covered_layers(&groups, 3);
        assert_eq!(covered, vec![true, false, true]);
    }

    #[test]
    fn weight_bytes_tracks_formats() {
        let q = qlayers3();
        let n = q.len();
        let bf16 = weight_bytes(&q, &MpConfig::all_bf16(n));
        assert_eq!(bf16, (64.0 + 128.0) * 2.0); // bgemm has no params
        let fp8 = weight_bytes(&q, &MpConfig::uniform(n, Format::Fp8E4m3));
        assert_eq!(fp8, 64.0 + 128.0);
        let grp = group_weight_bytes(&q, &[0, 2], &[Format::Fp8E4m3, Format::Bf16]);
        assert_eq!(grp, 64.0 + 256.0);
    }

    #[test]
    fn objective_names() {
        assert_eq!(Objective::EmpiricalTime.name(), "IP-ET");
        assert_eq!(Objective::TheoreticalTime.name(), "IP-TT");
        assert_eq!(Objective::Memory.name(), "IP-M");
    }
}
