//! # ampq — Automatic Mixed Precision with Constrained Loss-MSE
//!
//! Rust + JAX + Pallas reproduction of Markovich-Golan et al. (2025):
//! *"Automatic mixed precision for optimizing gained time with constrained
//! loss mean-squared-error based on model partition to sequential
//! sub-graphs"*.
//!
//! Layer map (see DESIGN.md):
//! * L3 (this crate): partition (Algorithm 2), sensitivity calibration,
//!   per-group time-gain measurement, MCKP/IP optimization, strategies,
//!   task evaluation, reporting — python is never on the request path.
//! * L2/L1 (python/compile, build-time only): the JAX transformer with
//!   runtime-controlled fake-quant Pallas kernels, lowered once to HLO text
//!   in `artifacts/` and executed here via PJRT (`runtime`).
//!
//! The public entry point is the **staged planning API** in [`plan`]:
//! an [`plan::Engine`] materializes cacheable stage artifacts
//! (`Partitioned -> Calibrated -> Measured`) once per model, and a
//! [`plan::Planner`] resolves multi-constraint [`plan::PlanRequest`]
//! queries (loss budget + optional memory cap + target device) in
//! microseconds, returning serializable [`plan::Plan`] values.
//! [`plan::Planner::frontier`] precomputes the tau -> gain Pareto curve —
//! for the IP strategy in one parametric chain-DP sweep
//! ([`solver::parametric`]) instead of one IP solve per knot — and
//! [`plan::PlanService`] serves both concurrently, routing per-device
//! requests to per-device planners.  Hardware lives in [`backend`]: a
//! [`backend::DeviceProfile`] (JSON-loadable; four built-ins in
//! [`backend::Registry`]) parameterizes the simulator, the theoretical
//! gain tables, and the format menus.  Stage fan-outs, solver
//! decomposition, frontier sweeps, and serve batches all run on the
//! deterministic parallel execution layer in [`exec`] (`--threads`):
//! output is bit-identical at any thread count.

#![allow(
    clippy::len_without_is_empty,
    clippy::inherent_to_string,
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_range_contains,
    clippy::type_complexity
)]

pub mod analyze;
pub mod backend;
pub mod coordinator;
pub mod dist;
pub mod evalharness;
pub mod exec;
pub mod figures;
pub mod gaudisim;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod numerics;
pub mod obs;
pub mod plan;
pub mod report;
pub mod runtime;
pub mod sensitivity;
pub mod serve;
pub mod solver;
pub mod tensorbin;
pub mod timing;
pub mod util;
