//! Tiny HTTP client for the planning daemon, used by CI's daemon smoke
//! job (curl is not assumed on the runner).
//!
//! ```text
//! ampq_client <addr> <method> <path> [--data JSON] [--expect-status N]
//!                                    [--retry N] [--trace ID]
//! ampq_client <addr> --load [--qps N] [--duration S] [--model NAME]
//!                           [--tau X] [--retry N] [--trace ID]
//! ```
//!
//! One-shot mode: the response body goes to stdout; with
//! `--expect-status`, a different actual status exits nonzero (after
//! printing the body), so shell pipelines can both grep the payload and
//! assert the status.  `--retry N` honors `Retry-After` on 503 under a
//! capped budget of N extra attempts.
//!
//! Load mode (`--load`): sustained mixed plan/frontier traffic at the
//! target QPS for the given duration, printing client-side p50/p99
//! latency and error counts, cross-checked against the daemon's own
//! `/metrics` counters (snapshot diff across the run).
//!
//! `--trace ID` stamps every request with an `x-ampq-trace` header so
//! the daemon stitches the whole run into one trace tree (inspect with
//! `GET /v1/trace/ID` or `ampq trace`).

// lint: allow-file(D3) load-harness latency measurement: this binary's whole job is wall-clock timing of daemon round-trips; nothing here feeds planning output

use ampq::serve::client::{
    request, request_with_headers, request_with_retry_headers, RetryPolicy,
};
use anyhow::{anyhow, bail, Result};
use std::io::Write;
use std::time::{Duration, Instant};

fn main() {
    if let Err(e) = run() {
        eprintln!("ampq_client: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.iter().any(|a| a == "--help") || argv.is_empty() {
        bail!(
            "usage: ampq_client <addr> <method> <path> [--data JSON] [--expect-status N] \
             [--retry N] [--trace ID]\n       ampq_client <addr> --load [--qps N] [--duration S] \
             [--model NAME] [--tau X] [--retry N] [--trace ID]"
        );
    }
    if argv.iter().any(|a| a == "--load") {
        return run_load(&argv);
    }
    if argv.len() < 3 {
        bail!("usage: ampq_client <addr> <method> <path> [--data JSON] [--expect-status N] [--retry N]");
    }
    let (addr, method, path) = (&argv[0], &argv[1], &argv[2]);
    let mut data: Option<String> = None;
    let mut expect: Option<u16> = None;
    let mut retry = 0usize;
    let mut trace: Option<String> = None;
    let mut i = 3;
    while i < argv.len() {
        match argv[i].as_str() {
            "--trace" => {
                i += 1;
                trace = Some(
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| anyhow!("--trace needs a value"))?,
                );
            }
            "--data" => {
                i += 1;
                data = Some(
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| anyhow!("--data needs a value"))?,
                );
            }
            "--expect-status" => {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| anyhow!("--expect-status needs a value"))?;
                expect = Some(v.parse().map_err(|_| anyhow!("bad status '{v}'"))?);
            }
            "--retry" => {
                i += 1;
                let v = argv.get(i).ok_or_else(|| anyhow!("--retry needs a value"))?;
                retry = v.parse().map_err(|_| anyhow!("bad retry budget '{v}'"))?;
            }
            other => bail!("unknown argument '{other}'"),
        }
        i += 1;
    }
    let headers: Vec<(&str, &str)> =
        trace.iter().map(|t| ("x-ampq-trace", t.as_str())).collect();
    let resp = if retry > 0 {
        let policy = RetryPolicy { budget: retry, ..RetryPolicy::default() };
        request_with_retry_headers(addr, method, path, data.as_deref(), &headers, policy)?
            .response
    } else {
        request_with_headers(addr, method, path, data.as_deref(), &headers)?
    };
    let mut out = std::io::stdout();
    out.write_all(&resp.body)?;
    if !resp.body.ends_with(b"\n") {
        out.write_all(b"\n")?;
    }
    out.flush()?;
    if let Some(want) = expect {
        if resp.status != want {
            bail!("status {} (expected {want})", resp.status);
        }
    }
    Ok(())
}

/// Sum of `ampq_requests_total{endpoint="...",...}` over all statuses for
/// the two solve endpoints, from the daemon's /metrics exposition text.
fn solve_requests_total(metrics: &str) -> u64 {
    metrics
        .lines()
        .filter(|l| l.starts_with("ampq_requests_total{"))
        .filter(|l| {
            l.contains("endpoint=\"/v1/plan\"") || l.contains("endpoint=\"/v1/frontier\"")
        })
        .filter_map(|l| l.rsplit(' ').next()?.trim().parse::<u64>().ok())
        .sum()
}

fn load_flag<T: std::str::FromStr>(argv: &[String], name: &str, default: T) -> Result<T> {
    match argv.iter().position(|a| a == name) {
        None => Ok(default),
        Some(i) => {
            let v = argv.get(i + 1).ok_or_else(|| anyhow!("{name} needs a value"))?;
            v.parse().map_err(|_| anyhow!("bad {name} value '{v}'"))
        }
    }
}

fn run_load(argv: &[String]) -> Result<()> {
    let addr = &argv[0];
    if addr.starts_with("--") {
        bail!("usage: ampq_client <addr> --load [--qps N] [--duration S] ...");
    }
    let qps: f64 = load_flag(argv, "--qps", 20.0)?;
    let duration: f64 = load_flag(argv, "--duration", 2.0)?;
    let model: String = load_flag(argv, "--model", "demo".to_string())?;
    let tau: f64 = load_flag(argv, "--tau", 0.004)?;
    let retry: usize = load_flag(argv, "--retry", 2)?;
    let trace: String = load_flag(argv, "--trace", String::new())?;
    if !(qps > 0.0) || !(duration > 0.0) {
        bail!("--qps and --duration must be positive");
    }
    let headers: Vec<(&str, &str)> = if trace.is_empty() {
        Vec::new()
    } else {
        vec![("x-ampq-trace", trace.as_str())]
    };
    let policy = RetryPolicy {
        budget: retry,
        max_wait: Duration::from_millis(250),
    };
    let plan_body = format!("{{\"model\":\"{model}\",\"objective\":\"et\",\"tau\":{tau}}}");
    let frontier_body = format!("{{\"model\":\"{model}\"}}");

    let before = request(addr, "GET", "/metrics", None)?.text()?;
    let base = solve_requests_total(&before);

    let interval = Duration::from_secs_f64(1.0 / qps);
    let start = Instant::now();
    let t_end = start + Duration::from_secs_f64(duration);
    let mut latencies_us: Vec<f64> = Vec::new();
    let (mut sent, mut ok, mut http_errors, mut transport_errors) = (0u64, 0u64, 0u64, 0u64);
    let mut attempts_total = 0u64;
    while Instant::now() < t_end {
        // Open-loop pacing: each request has a scheduled send time; a slow
        // server makes us late, not slower (that is the point of a load
        // test).
        let scheduled = start + interval.mul_f64(sent as f64);
        let now = Instant::now();
        if scheduled > now {
            std::thread::sleep(scheduled - now);
        }
        // Mixed traffic: every 5th request sweeps a frontier, the rest
        // solve plans (the frontier side is cache-hot after the first).
        let (path, body) = if sent % 5 == 4 {
            ("/v1/frontier", frontier_body.as_str())
        } else {
            ("/v1/plan", plan_body.as_str())
        };
        let t0 = Instant::now();
        match request_with_retry_headers(addr, "POST", path, Some(body), &headers, policy) {
            Ok(r) => {
                attempts_total += r.attempts as u64;
                if r.response.status == 200 {
                    ok += 1;
                    latencies_us.push(t0.elapsed().as_secs_f64() * 1e6);
                } else {
                    http_errors += 1;
                    if http_errors <= 3 {
                        eprintln!("load: {path} -> {}", r.response.status);
                    }
                }
            }
            Err(e) => {
                transport_errors += 1;
                if transport_errors <= 3 {
                    eprintln!("load: {path} -> transport error: {e:#}");
                }
            }
        }
        sent += 1;
    }
    let elapsed = start.elapsed().as_secs_f64();

    latencies_us.sort_by(|a, b| a.total_cmp(b));
    let pct = |q: f64| -> f64 {
        if latencies_us.is_empty() {
            return f64::NAN;
        }
        let idx = ((latencies_us.len() - 1) as f64 * q).round() as usize;
        latencies_us[idx]
    };
    println!(
        "load: {sent} requests in {elapsed:.2}s ({:.1} qps achieved, {qps:.1} target): \
         {ok} ok, {http_errors} http errors, {transport_errors} transport errors",
        sent as f64 / elapsed
    );
    println!("client latency: p50 {:.0} us, p99 {:.0} us", pct(0.50), pct(0.99));

    // Cross-check: the daemon's own request counters must account for
    // every attempt we made (retries included).  Requests that died in
    // transport may or may not have been counted server-side, so the
    // strict check only runs on a clean-transport run.
    let after = request(addr, "GET", "/metrics", None)?.text()?;
    let served = solve_requests_total(&after) - base;
    println!("server /metrics: {served} solve requests this run (client sent {attempts_total} attempts)");
    if transport_errors == 0 && served != attempts_total {
        bail!("metrics cross-check failed: server counted {served}, client sent {attempts_total}");
    }
    if ok == 0 {
        bail!("load run produced no successful responses");
    }
    Ok(())
}
