//! Tiny HTTP client for the planning daemon, used by CI's daemon smoke
//! job (curl is not assumed on the runner).
//!
//! ```text
//! ampq_client <addr> <method> <path> [--data JSON] [--expect-status N]
//! ```
//!
//! The response body goes to stdout.  With `--expect-status`, a
//! different actual status exits nonzero (after printing the body), so
//! shell pipelines can both grep the payload and assert the status.

use anyhow::{anyhow, bail, Result};
use std::io::Write;

fn main() {
    if let Err(e) = run() {
        eprintln!("ampq_client: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.len() < 3 || argv.iter().any(|a| a == "--help") {
        bail!("usage: ampq_client <addr> <method> <path> [--data JSON] [--expect-status N]");
    }
    let (addr, method, path) = (&argv[0], &argv[1], &argv[2]);
    let mut data: Option<String> = None;
    let mut expect: Option<u16> = None;
    let mut i = 3;
    while i < argv.len() {
        match argv[i].as_str() {
            "--data" => {
                i += 1;
                data = Some(
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| anyhow!("--data needs a value"))?,
                );
            }
            "--expect-status" => {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| anyhow!("--expect-status needs a value"))?;
                expect = Some(v.parse().map_err(|_| anyhow!("bad status '{v}'"))?);
            }
            other => bail!("unknown argument '{other}'"),
        }
        i += 1;
    }
    let resp = ampq::serve::client::request(addr, method, path, data.as_deref())?;
    let mut out = std::io::stdout();
    out.write_all(&resp.body)?;
    if !resp.body.ends_with(b"\n") {
        out.write_all(b"\n")?;
    }
    out.flush()?;
    if let Some(want) = expect {
        if resp.status != want {
            bail!("status {} (expected {want})", resp.status);
        }
    }
    Ok(())
}
