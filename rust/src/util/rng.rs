//! Deterministic PRNG (xoshiro256** seeded via splitmix64).
//!
//! The rand crate is not vendored in this image, and every experiment in the
//! paper's protocol (Random baseline, scale-perturbation seeds, simulator
//! measurement noise) must be reproducible from a single u64 seed — so we
//! carry our own small generator.

/// xoshiro256** — fast, high-quality, deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (e.g. per task / per seed index).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// The `idx`-th independent stream of `seed` — a pure function of
    /// `(seed, idx)`, so parallel tasks can each draw their own generator
    /// with no shared state and no dependence on execution order.  This is
    /// the parallel execution layer's RNG primitive (see `crate::exec`):
    /// a stage that assigns stream indices in its sequential enumeration
    /// order produces bit-identical randomness at any thread count.
    pub fn stream(seed: u64, idx: u64) -> Rng {
        // Mix seed and index through two rounds of splitmix64 so adjacent
        // indices land in unrelated states (a plain XOR would correlate
        // stream 0 with the base seed).
        let mut sm = seed;
        let a = splitmix64(&mut sm);
        let mut sm2 = idx.wrapping_mul(0x9e3779b97f4a7c15) ^ a;
        Rng::new(splitmix64(&mut sm2))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free bound is overkill here; modulo bias is
        // negligible for our n << 2^64 use.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..200_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(9);
        let ks = r.choose_k(20, 8);
        assert_eq!(ks.len(), 8);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(123);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn stream_is_pure_in_seed_and_index() {
        let first: Vec<u64> = {
            let mut r = Rng::stream(42, 7);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let second: Vec<u64> = {
            let mut r = Rng::stream(42, 7);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_eq!(first, second);
        let mut other = Rng::stream(42, 8);
        assert_ne!(first[0], other.next_u64());
        let mut other_seed = Rng::stream(43, 7);
        assert_ne!(first[0], other_seed.next_u64());
    }

    #[test]
    fn stream_zero_differs_from_base_seed() {
        let mut base = Rng::new(42);
        let mut s0 = Rng::stream(42, 0);
        assert_ne!(
            (0..8).map(|_| base.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| s0.next_u64()).collect::<Vec<_>>()
        );
    }
}
