//! Small statistics helpers shared by timing, eval, and report.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Unbiased (n-1) standard deviation; 0.0 for fewer than two samples.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (average of middle two for even n); 0.0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 { v[n / 2] } else { 0.5 * (v[n / 2 - 1] + v[n / 2]) }
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi { v[lo] } else { v[lo] + (rank - lo as f64) * (v[hi] - v[lo]) }
}

/// Pearson correlation coefficient; 0.0 when degenerate.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx <= 0.0 || syy <= 0.0 { 0.0 } else { sxy / (sxx * syy).sqrt() }
}

/// Least-squares fit y ~= a*x + b; returns (a, b).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let a = if den > 0.0 { num / den } else { 0.0 };
    (a, my - a * mx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x - 1.0).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-12);
        assert!((b + 1.0).abs() < 1e-12);
    }
}
