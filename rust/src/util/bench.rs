//! Minimal benchmarking harness (criterion is not vendored in this image).
//!
//! Used by the `benches/` targets (`cargo bench`, harness = false).  Reports
//! mean / median / p95 over timed iterations after a warmup, in a stable
//! one-line format that EXPERIMENTS.md §Perf records.

use super::stats;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "bench {:<44} iters {:>5}  mean {:>12.2} us  median {:>12.2} us  p95 {:>12.2} us",
            self.name, self.iters, self.mean_us, self.median_us, self.p95_us
        )
    }
}

/// Time `f` for `iters` iterations (after `warmup` unrecorded calls).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_us: stats::mean(&samples),
        median_us: stats::median(&samples),
        p95_us: stats::percentile(&samples, 95.0),
    };
    println!("{}", r.report());
    r
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 50, || {
            black_box((0..100).sum::<usize>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.mean_us >= 0.0);
        assert!(r.p95_us >= r.median_us * 0.5);
    }
}
