//! Minimal benchmarking harness (criterion is not vendored in this image).
//!
//! Used by the `benches/` targets (`cargo bench`, harness = false).  Reports
//! mean / median / p95 over timed iterations after a warmup, in a stable
//! one-line format that EXPERIMENTS.md §Perf records.

// lint: allow-file(D3) the benchmark harness IS a stopwatch; timings go to BENCH_*.json summaries, never into planning artifacts

use super::stats;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "bench {:<44} iters {:>5}  mean {:>12.2} us  median {:>12.2} us  p95 {:>12.2} us",
            self.name, self.iters, self.mean_us, self.median_us, self.p95_us
        )
    }

    /// Machine-readable form for BENCH_*.json summaries (the perf
    /// trajectory's data points).
    // lint: allow(D5) write-only bench summary; gating reads it from python, not rust
    pub fn to_json(&self) -> super::Json {
        use super::Json;
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("iters".into(), Json::Num(self.iters as f64)),
            ("mean_us".into(), Json::Num(self.mean_us)),
            ("median_us".into(), Json::Num(self.median_us)),
            ("p95_us".into(), Json::Num(self.p95_us)),
        ])
    }
}

/// Write a `BENCH_<name>.json` summary: the timed results plus free-form
/// extra fields (quality ratios, instance sizes, ...).
pub fn write_summary(
    path: &std::path::Path,
    name: &str,
    results: &[BenchResult],
    extra: Vec<(String, super::Json)>,
) -> std::io::Result<()> {
    use super::Json;
    let mut kv = vec![
        ("bench".to_string(), Json::Str(name.to_string())),
        (
            "results".to_string(),
            Json::Arr(results.iter().map(|r| r.to_json()).collect()),
        ),
    ];
    kv.extend(extra);
    std::fs::write(path, Json::Obj(kv).to_string())
}

/// Time `f` for `iters` iterations (after `warmup` unrecorded calls).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_us: stats::mean(&samples),
        median_us: stats::median(&samples),
        p95_us: stats::percentile(&samples, 95.0),
    };
    println!("{}", r.report());
    r
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 50, || {
            black_box((0..100).sum::<usize>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.mean_us >= 0.0);
        assert!(r.p95_us >= r.median_us * 0.5);
    }

    #[test]
    fn summary_writes_parseable_json() {
        let r = bench("unit", 0, 5, || {
            black_box(1 + 1);
        });
        let path = std::env::temp_dir()
            .join(format!("ampq_bench_summary_{}.json", std::process::id()));
        write_summary(
            &path,
            "unit",
            &[r],
            vec![("note".into(), crate::util::Json::Str("x".into()))],
        )
        .unwrap();
        let j = crate::util::Json::parse_file(&path).unwrap();
        assert_eq!(j.get("bench").unwrap().str().unwrap(), "unit");
        assert_eq!(j.get("results").unwrap().arr().unwrap().len(), 1);
        assert_eq!(j.get("note").unwrap().str().unwrap(), "x");
        std::fs::remove_file(&path).ok();
    }
}
