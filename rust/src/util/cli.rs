//! Tiny CLI argument parser (clap is not vendored in this image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the binary name). `flag_names` lists options
    /// that take no value.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    i += 1;
                    let v = raw
                        .get(i)
                        .ok_or_else(|| anyhow!("option --{body} needs a value"))?;
                    out.options.insert(body.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&sv(&["run", "--tau", "0.5", "--force", "--x=3"]), &["force"]).unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("tau"), Some("0.5"));
        assert!(a.flag("force"));
        assert_eq!(a.usize_or("x", 0).unwrap(), 3);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--tau"]), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]), &[]).unwrap();
        assert_eq!(a.get_or("model", "tiny-s"), "tiny-s");
        assert_eq!(a.f64_or("tau", 0.1).unwrap(), 0.1);
        assert!(!a.flag("anything"));
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&sv(&["--n", "abc"]), &[]).unwrap();
        assert!(a.usize_or("n", 1).is_err());
    }
}
