//! Minimal JSON parser/serializer (serde_json is not vendored in this image).
//!
//! Supports the full JSON grammar we emit from python (objects, arrays,
//! strings with escapes, numbers, bools, null).  Object key order is
//! preserved (Vec of pairs) so round-trips are stable.

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(kv) => kv
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn i64(&self) -> Result<i64> {
        Ok(self.f64()? as i64)
    }

    pub fn usize(&self) -> Result<usize> {
        let x = self.f64()?;
        if x < 0.0 {
            bail!("negative where usize expected");
        }
        Ok(x as usize)
    }

    // ---- serializer ------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Collect full UTF-8 sequences.
                    let start = self.i - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().str().unwrap(),
            "x"
        );
        assert_eq!(*j.get("c").unwrap(), Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-7}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn accessor_errors() {
        let j = Json::parse(r#"{"a": 1, "f": true}"#).unwrap();
        assert!(j.get("b").is_err());
        assert!(j.get("a").unwrap().str().is_err());
        assert_eq!(j.get("a").unwrap().usize().unwrap(), 1);
        assert!(j.opt("missing").is_none());
        assert!(j.get("f").unwrap().bool().unwrap());
        assert!(j.get("a").unwrap().bool().is_err());
    }

    #[test]
    fn preserves_key_order() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Json::Obj(kv) = &j {
            let keys: Vec<&str> = kv.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!()
        }
    }
}
