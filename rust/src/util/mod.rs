//! Shared utilities: deterministic PRNG, statistics, JSON, CLI parsing.
//!
//! These exist because the image's vendored crate set does not include
//! rand / serde_json / clap / criterion — see DESIGN.md §3 (substitutions).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
