//! Stub of the PJRT/XLA binding surface `ampq::runtime` compiles against.
//!
//! The real system executes AOT-lowered HLO-text artifacts through PJRT
//! (see python/compile/aot.py).  This image has no XLA runtime library to
//! link, so this crate keeps the exact API shape while every entry point
//! that would touch PJRT fails at *runtime* with a descriptive error.
//!
//! To run the compiled-HLO paths for real, replace the `xla` entry in
//! rust/Cargo.toml with actual PJRT bindings exposing this same surface:
//! `PjRtClient::cpu`, `platform_name`, `compile`,
//! `HloModuleProto::from_text_file`, `XlaComputation::from_proto`,
//! `PjRtLoadedExecutable::execute`, `PjRtBuffer::to_literal_sync`,
//! `Literal::{vec1, reshape, to_tuple2, to_vec}`.
//!
//! Everything simulator-backed (partition, calibration from cached
//! artifacts, time measurement, IP planning, `ampq sweep --demo`) works
//! without PJRT; only live calibration / task evaluation / wall-clock TTFT
//! need the real bindings.

use std::fmt;

const UNAVAILABLE: &str = "PJRT is unavailable: ampq was built against the vendored xla stub \
     (rust/vendor/xla); swap in real PJRT bindings to run compiled HLO";

/// Error type mirrored from the binding layer (call sites format with `{:?}`).
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side tensor value handed to / fetched from executables.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Parsed HLO module (the AOT interchange format is HLO text).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// A computation ready for compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable bound to a client.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle (CPU platform in the real deployment).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(format!("{err:?}").contains("vendored xla stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn literal_shape_plumbing_is_inert() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
