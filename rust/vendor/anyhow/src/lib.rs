//! Vendored minimal subset of the `anyhow` API.
//!
//! The build image has no crates.io access, so this path crate provides the
//! slice of anyhow the workspace actually uses: `Error`, `Result`, the
//! `anyhow!` / `bail!` / `ensure!` macros, a `Context` extension trait, and
//! `From<E: std::error::Error>` so `?` converts std error types.
//!
//! Like real anyhow, `Error` deliberately does NOT implement
//! `std::error::Error` — that is what makes the blanket `From` impl legal.

use std::fmt;

/// A dynamically typed error message with an optional context chain.
pub struct Error {
    /// Outermost message first; contexts are pushed to the front.
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, outermost first ("a: b: c").
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "boom 42");
        assert_eq!(format!("{e:#}"), "boom 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert!(parse("12").is_ok());
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_chains() {
        let e: Result<()> = Err(Error::msg("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e}"), "outer");
    }

    #[test]
    fn inline_format_captures() {
        let x = 7;
        let e = anyhow!("x = {x}");
        assert_eq!(format!("{e}"), "x = 7");
    }
}
