//! Solver edge cases and cross-solver agreement (issue satellite):
//! empty group lists, budget = 0, single-choice groups, and B&B vs DP vs
//! greedy agreement on random small MCKP instances — plus the tau = 0 IP
//! behaviour (all-BF16 fallback) at the coordinator layer.

use ampq::coordinator::optimize;
use ampq::exec::ExecPool;
use ampq::metrics::GroupChoices;
use ampq::numerics::Format;
use ampq::sensitivity::Calibration;
use ampq::solver::{branch_bound, dp, greedy, lp_relax, Mckp};
use ampq::util::Rng;

#[test]
fn empty_group_list_is_feasible_with_zero_gain() {
    let p = Mckp::new(vec![], vec![], 0.0).unwrap();
    for sol in [p.brute_force(), branch_bound::solve(&p), dp::solve(&p), greedy::solve(&p)] {
        assert!(sol.feasible);
        assert!(sol.choice.is_empty());
        assert_eq!(sol.gain, 0.0);
        assert_eq!(sol.cost, 0.0);
    }
    assert_eq!(lp_relax::solve(&p).bound, 0.0);
}

#[test]
fn zero_budget_returns_all_baseline_and_stays_feasible() {
    // Every group's baseline option costs nothing (the all-BF16 row of a
    // normalized family): budget = 0 must stay feasible and pick exactly
    // the baseline in every group.
    let p = Mckp::new(
        vec![vec![0.0, 7.0], vec![0.0, 3.0], vec![0.0, 9.0]],
        vec![vec![0.0, 0.5], vec![0.0, 0.25], vec![0.0, 1.0]],
        0.0,
    )
    .unwrap();
    for sol in [p.brute_force(), branch_bound::solve(&p), dp::solve(&p), greedy::solve(&p)] {
        assert!(sol.feasible, "budget 0 with zero-cost baselines must be feasible");
        assert_eq!(sol.choice, vec![0, 0, 0]);
        assert_eq!(sol.gain, 0.0);
    }
}

#[test]
fn ip_tau_zero_returns_all_bf16() {
    // Coordinator layer: at tau = 0 the constraint admits nothing (even
    // BF16 has nonzero predicted MSE), so the IP falls back to the
    // all-BF16 configuration — the paper's tau = 0 edge.
    let calib = Calibration { s: vec![1.0, 2.0, 0.5], eg2: 1.0, g_mean: 1.0, n_samples: 4 };
    let groups: Vec<GroupChoices> = (0..3)
        .map(|l| GroupChoices {
            qidxs: vec![l],
            configs: vec![vec![Format::Bf16], vec![Format::Fp8E4m3]],
            gains: vec![0.0, 1.0],
        })
        .collect();
    let out = optimize(&groups, &calib, 0.0, &ExecPool::sequential()).unwrap();
    assert_eq!(out.config.n_quantized(), 0, "tau=0 must return all-BF16");
    assert_eq!(out.budget, 0.0);
}

#[test]
fn single_choice_groups_are_forced() {
    // One option per group: the only possible assignment; feasibility is
    // decided purely by the budget.
    let gains = vec![vec![2.0], vec![3.0], vec![4.0]];
    let costs = vec![vec![1.0], vec![1.0], vec![1.0]];
    let fits = Mckp::new(gains.clone(), costs.clone(), 3.5).unwrap();
    for sol in
        [fits.brute_force(), branch_bound::solve(&fits), dp::solve(&fits), greedy::solve(&fits)]
    {
        assert!(sol.feasible);
        assert_eq!(sol.choice, vec![0, 0, 0]);
        assert!((sol.gain - 9.0).abs() < 1e-12);
    }
    let tight = Mckp::new(gains, costs, 2.0).unwrap();
    for sol in
        [tight.brute_force(), branch_bound::solve(&tight), dp::solve(&tight), greedy::solve(&tight)]
    {
        assert!(!sol.feasible, "forced assignment over budget must be infeasible");
        assert_eq!(sol.choice, vec![0, 0, 0], "fallback is still the min-cost choice");
    }
}

#[test]
fn mixed_single_and_multi_choice_groups() {
    // A forced expensive group plus a real choice: the solver must spend
    // what the forced group leaves over.
    let p = Mckp::new(
        vec![vec![5.0], vec![0.0, 2.0, 6.0]],
        vec![vec![2.0], vec![0.0, 1.0, 3.0]],
        3.5,
    )
    .unwrap();
    let exact = p.brute_force();
    let bb = branch_bound::solve(&p);
    assert!(exact.feasible && bb.feasible);
    assert_eq!(bb.choice, exact.choice);
    assert_eq!(bb.choice, vec![0, 1]); // 6.0 would need cost 3 > 1.5 left
    assert!((bb.gain - 7.0).abs() < 1e-12);
}

fn random_instance(rng: &mut Rng) -> Mckp {
    let j = rng.range(1, 6);
    let mut gains = Vec::new();
    let mut costs = Vec::new();
    for _ in 0..j {
        let k = rng.range(1, 6);
        gains.push((0..k).map(|_| rng.f64() * 10.0).collect::<Vec<f64>>());
        costs.push((0..k).map(|_| rng.f64() * 3.0).collect::<Vec<f64>>());
    }
    let lo: f64 = costs
        .iter()
        .map(|c| c.iter().cloned().fold(f64::MAX, f64::min))
        .sum();
    let hi: f64 = costs
        .iter()
        .map(|c| c.iter().cloned().fold(0.0f64, f64::max))
        .sum();
    let budget = lo + rng.f64() * (hi - lo).max(0.01);
    Mckp::new(gains, costs, budget).unwrap()
}

#[test]
fn solvers_agree_on_random_small_instances() {
    for seed in 0..150u64 {
        let mut rng = Rng::new(0x5EED ^ seed);
        let p = random_instance(&mut rng);
        let exact = p.brute_force();
        let bb = branch_bound::solve(&p);
        let d = dp::solve(&p);
        let g = greedy::solve(&p);
        let lp = lp_relax::solve(&p);

        assert_eq!(bb.feasible, exact.feasible, "seed {seed}");
        assert_eq!(g.feasible, exact.feasible, "seed {seed}");
        // DP rounds costs UP onto the bucket grid, so it can only miss
        // feasibility on knife-edge budgets — never invent it.
        if !exact.feasible {
            assert!(!d.feasible, "seed {seed}: dp cannot out-feasible brute force");
            continue;
        }
        // Exact == brute force; heuristics feasible and dominated; LP is an
        // upper bound.
        assert!((bb.gain - exact.gain).abs() < 1e-9, "seed {seed}");
        assert!(bb.cost <= p.budget() + 1e-9, "seed {seed}");
        assert!(g.cost <= p.budget() + 1e-9, "seed {seed}");
        assert!(g.gain <= exact.gain + 1e-9, "seed {seed}");
        if d.feasible {
            assert!(d.cost <= p.budget() + 1e-9, "seed {seed}");
            assert!(d.gain <= exact.gain + 1e-9, "seed {seed}");
        }
        assert!(lp.bound >= exact.gain - 1e-9, "seed {seed}");
    }
}
