//! Acceptance tests for the observability layer (`src/obs/`).
//!
//! The load-bearing contract is OBSERVATION-ONLY tracing: every plan,
//! frontier, daemon answer, and fleet artifact is bit-identical with
//! tracing on or off, at any thread count and any worker count.  The
//! rest covers the daemon's trace plumbing — `x-ampq-trace` validation
//! and echo, `GET /v1/trace/:id`, `/metrics` content negotiation — and
//! the span/counter payloads the solver and engine stages record.

use ampq::backend::DeviceProfile;
use ampq::coordinator::Strategy;
use ampq::exec::ExecCfg;
use ampq::metrics::Objective;
use ampq::obs;
use ampq::plan::demo::demo_model;
use ampq::plan::{Engine, PlanRequest, PlanService, ServeRequest};
use ampq::serve::client::{request as one_shot, request_with_headers, Client};
use ampq::serve::{Daemon, ServeConfig};
use ampq::util::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

/// The recording flag is process-wide; tests that toggle it (or assert
/// that spans were recorded) serialize here so a concurrent test never
/// observes a surprise flip.
static OBS_FLAG: Mutex<()> = Mutex::new(());

fn flag_lock() -> std::sync::MutexGuard<'static, ()> {
    OBS_FLAG.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Solve one demo plan + frontier on a fresh engine and return both
/// serializations — the bytes the bit-identity tests compare.
fn solve_bytes(threads: usize) -> (String, String) {
    let (graph, qlayers, calibration) = demo_model(2, 3);
    let mut engine = Engine::new().with_exec(ExecCfg::new(threads));
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let planner = engine.planner("demo").unwrap();
    let plan = planner
        .solve(&PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004))
        .unwrap();
    let frontier = planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
    (plan.to_json().to_string(), frontier.to_json().to_string())
}

#[test]
fn tracing_never_changes_plan_or_frontier_bytes() {
    let _g = flag_lock();
    let was = obs::enabled();
    obs::set_enabled(false);
    let reference = solve_bytes(1);
    let untraced_par = solve_bytes(4);
    obs::set_enabled(true);
    let traced_seq = obs::with_trace("obs-bit-identity", || solve_bytes(1));
    let traced_par = obs::with_trace("obs-bit-identity", || solve_bytes(4));
    obs::set_enabled(was);
    assert_eq!(reference, untraced_par, "thread count changed bytes");
    assert_eq!(reference, traced_seq, "tracing changed sequential bytes");
    assert_eq!(reference, traced_par, "tracing changed parallel bytes");
}

// ---------------------------------------------------------------- fleet

/// Every file under `root`, keyed by relative path (fleet artifacts are
/// all JSON text).
fn read_tree(root: &Path) -> BTreeMap<String, String> {
    fn walk(dir: &Path, root: &Path, out: &mut BTreeMap<String, String>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                walk(&path, root, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                out.insert(rel, std::fs::read_to_string(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

fn fleet_tree(tag: &str, workers: usize) -> (BTreeMap<String, String>, ampq::dist::DistMetrics) {
    let out = std::env::temp_dir().join(format!("ampq_obs_{}_{tag}", std::process::id()));
    std::fs::remove_dir_all(&out).ok();
    let cfg = ampq::dist::FleetConfig {
        models: vec!["demo".to_string()],
        devices: vec!["gaudi2".to_string()],
        workers,
        out: out.clone(),
        blocks: 1,
        dist: ampq::dist::DistConfig {
            workers,
            worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_ampq"))),
            retry_backoff: Duration::from_millis(10),
            ..ampq::dist::DistConfig::default()
        },
    };
    let report = ampq::dist::run_fleet(&cfg).unwrap_or_else(|e| panic!("{tag}: {e:#}"));
    let tree = read_tree(&out);
    std::fs::remove_dir_all(&out).ok();
    (tree, report.metrics)
}

/// Fleet artifacts are byte-identical untraced vs traced, in-process vs
/// over real worker subprocesses — and the traced distributed run ships
/// worker-process spans back into the coordinator's trace tree.
#[test]
fn fleet_artifacts_identical_with_tracing_on_across_worker_counts() {
    let _g = flag_lock();
    let was = obs::enabled();
    obs::set_enabled(false);
    let (reference, m0) = fleet_tree("ref", 0);
    assert_eq!(m0, ampq::dist::DistMetrics::default());
    assert!(!reference.is_empty(), "reference fleet produced no artifacts");

    obs::set_enabled(true);
    let t_inproc = obs::fresh_trace_id();
    let (traced0, _) = obs::with_trace(&t_inproc, || fleet_tree("t0", 0));
    let t_fleet = obs::fresh_trace_id();
    let (traced2, m2) = obs::with_trace(&t_fleet, || fleet_tree("t2", 2));
    obs::set_enabled(was);

    assert_eq!(reference, traced0, "tracing changed in-process fleet artifacts");
    assert_eq!(reference, traced2, "tracing changed distributed fleet artifacts");
    assert!(m2.tasks > 0, "no tasks reached the fleet");

    // Worker spans must be adopted into the coordinator's trace.
    let spans = obs::spans_for(&t_fleet);
    assert!(
        spans.iter().any(|s| s.name == "dist.run_tasks"),
        "coordinator batch span missing: {:?}",
        spans.iter().map(|s| s.name.as_str()).collect::<Vec<_>>()
    );
    assert!(
        spans.iter().any(|s| s.name.starts_with("worker.")),
        "no worker-process spans were stitched into the trace"
    );
    // Stitched spans keep their origin pid: at least one span must come
    // from a process that is not this one.
    let here = u64::from(std::process::id());
    assert!(
        spans.iter().any(|s| s.pid != here),
        "all spans claim the coordinator pid; shipping lost origin pids"
    );
}

/// The solver and engine stages record introspection counters on their
/// spans (DP states kept/pruned per group, frontier knots, stage cache
/// hits) without touching outputs.
#[test]
fn solver_and_stage_spans_carry_counters() {
    let _g = flag_lock();
    let was = obs::enabled();
    obs::set_enabled(true);
    let id = "obs-solver-counters";
    obs::with_trace(id, || {
        let (graph, qlayers, calibration) = demo_model(2, 9);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        let planner = engine.planner("demo").unwrap();
        planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
    });
    obs::set_enabled(was);

    let spans = obs::spans_for(id);
    let frontier = spans
        .iter()
        .find(|s| s.name == "solver.frontier")
        .expect("solver.frontier span missing");
    assert!(frontier.counters.iter().any(|(k, _)| k == "knots"));
    assert!(frontier.counters.iter().any(|(k, _)| k == "groups"));
    let dp: Vec<_> = spans.iter().filter(|s| s.name == "solver.dp.group").collect();
    assert!(!dp.is_empty(), "no per-group DP spans recorded");
    for sp in &dp {
        for key in ["candidates", "kept", "pruned"] {
            assert!(
                sp.counters.iter().any(|(k, _)| k == key),
                "DP span missing counter '{key}': {:?}",
                sp.counters
            );
        }
    }
    assert!(
        spans.iter().any(|s| s.name == "stage.measure"),
        "engine stage spans missing"
    );
}

// --------------------------------------------------------------- daemon

fn build_service() -> PlanService {
    let (graph, qlayers, calibration) = demo_model(1, 7);
    let mut engine = Engine::new();
    engine.register_synthetic("demo", graph, qlayers, calibration);
    PlanService::from_engine(&mut engine, &["demo"]).unwrap()
}

struct TestDaemon {
    daemon: Arc<Daemon>,
    addr: String,
    join: Option<std::thread::JoinHandle<()>>,
}

impl TestDaemon {
    fn start(mut cfg: ServeConfig) -> TestDaemon {
        cfg.addr = "127.0.0.1:0".to_string();
        let daemon = Arc::new(Daemon::new(build_service(), vec![DeviceProfile::gaudi2()], cfg));
        let listener = daemon.bind().unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let d = daemon.clone();
        let join = std::thread::spawn(move || d.run(listener).unwrap());
        TestDaemon { daemon, addr, join: Some(join) }
    }

    fn stop(mut self) {
        self.daemon.handle().shutdown();
        self.join.take().unwrap().join().unwrap();
    }
}

impl Drop for TestDaemon {
    fn drop(&mut self) {
        self.daemon.handle().shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn plan_body() -> String {
    ServeRequest::new("demo", PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004))
        .to_json()
        .to_string()
}

/// The `tracing` serve flag changes what is recorded, never what is
/// answered.
#[test]
fn daemon_answers_identical_with_tracing_on_and_off() {
    let body = plan_body();
    let mut rounds: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
    for tracing in [false, true] {
        let td = TestDaemon::start(ServeConfig { tracing, ..ServeConfig::default() });
        let mut c = Client::connect(&td.addr).unwrap();
        let p = c.request("POST", "/v1/plan", Some(body.as_str())).unwrap();
        assert_eq!(p.status, 200, "body: {}", p.text().unwrap());
        let f = c.request("POST", "/v1/frontier", Some("{\"model\":\"demo\"}")).unwrap();
        assert_eq!(f.status, 200);
        rounds.push((p.body, f.body));
        td.stop();
    }
    assert_eq!(rounds[0], rounds[1], "the tracing flag changed daemon answer bytes");
}

#[test]
fn daemon_validates_echoes_and_serves_traces() {
    let _g = flag_lock();
    obs::set_enabled(true); // ServeConfig::default() enables too; be explicit
    let td = TestDaemon::start(ServeConfig::default());
    let body = plan_body();

    // A supplied id is echoed on the response and queryable afterwards.
    let id = "obs-daemon-trace-1";
    let resp = request_with_headers(
        &td.addr,
        "POST",
        "/v1/plan",
        Some(body.as_str()),
        &[("x-ampq-trace", id)],
    )
    .unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("x-ampq-trace"), Some(id), "trace id not echoed");

    let tree = one_shot(&td.addr, "GET", &format!("/v1/trace/{id}"), None).unwrap();
    assert_eq!(tree.status, 200);
    let t = Json::parse(&tree.text().unwrap()).unwrap();
    assert_eq!(t.get("trace").unwrap().str().unwrap(), id);
    assert!(t.get("span_count").unwrap().usize().unwrap() >= 1);
    let roots = t.get("roots").unwrap().arr().unwrap();
    assert!(
        roots.iter().any(|r| r.get("name").unwrap().str().unwrap().starts_with("daemon.")),
        "request root is not a daemon span: {}",
        t.to_string()
    );

    // Without a header the daemon stamps (and echoes) a fresh id.
    let resp = one_shot(&td.addr, "POST", "/v1/plan", Some(body.as_str())).unwrap();
    assert_eq!(resp.status, 200);
    let fresh = resp.header("x-ampq-trace").expect("daemon must stamp a trace id");
    assert!(!fresh.is_empty() && fresh != id);

    // Unknown trace: 404.  Hostile ids in the path: 400.  Wrong method: 405.
    assert_eq!(
        one_shot(&td.addr, "GET", "/v1/trace/never-recorded-id", None).unwrap().status,
        404
    );
    let long = "x".repeat(65);
    assert_eq!(
        one_shot(&td.addr, "GET", &format!("/v1/trace/{long}"), None).unwrap().status,
        400
    );
    assert_eq!(one_shot(&td.addr, "POST", "/v1/trace/abc", Some("{}")).unwrap().status, 405);

    // An invalid request header is a client error, not a solve.
    let bad = request_with_headers(
        &td.addr,
        "POST",
        "/v1/plan",
        Some(body.as_str()),
        &[("x-ampq-trace", "no/slashes!allowed")],
    )
    .unwrap();
    assert_eq!(bad.status, 400);
    let parsed = Json::parse(&bad.text().unwrap()).unwrap();
    assert_eq!(parsed.get("kind").unwrap().str().unwrap(), "error");
    td.stop();
}

#[test]
fn metrics_negotiates_prometheus_text_and_json() {
    let td = TestDaemon::start(ServeConfig::default());
    let mut c = Client::connect(&td.addr).unwrap();
    let body = plan_body();
    assert_eq!(c.request("POST", "/v1/plan", Some(body.as_str())).unwrap().status, 200);

    let text = c.request("GET", "/metrics", None).unwrap();
    assert_eq!(text.status, 200);
    assert!(text.text().unwrap().contains("ampq_requests_total{"));

    let json = c
        .request_with_headers("GET", "/metrics", None, &[("Accept", "application/json")])
        .unwrap();
    assert_eq!(json.status, 200);
    let parsed = Json::parse(&json.text().unwrap()).unwrap();
    assert!(!parsed.get("requests").unwrap().arr().unwrap().is_empty());
    parsed.get("gauges").unwrap().get("queue_depth").unwrap().f64().unwrap();
    parsed.get("plan_latency").unwrap().get("count").unwrap().f64().unwrap();
    td.stop();
}

/// Supervision counters installed on the daemon's metrics (the
/// `--dist-workers` staging path) surface as `ampq_dist_*`.
#[test]
fn dist_metrics_surface_on_the_daemon_exposition() {
    let td = TestDaemon::start(ServeConfig::default());
    td.daemon.metrics().set_dist(ampq::dist::DistMetrics {
        tasks: 3,
        retries: 1,
        ..Default::default()
    });
    let m = one_shot(&td.addr, "GET", "/metrics", None).unwrap().text().unwrap();
    assert!(m.contains("ampq_dist_tasks_total 3\n"), "{m}");
    assert!(m.contains("ampq_dist_retries_total 1\n"));
    td.stop();
}
