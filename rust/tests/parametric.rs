//! Acceptance suite for the parametric one-pass frontier solver (chain DP
//! over sequential sub-graphs) and the solver/frontier panic-hardening
//! satellites:
//!
//! * the one-pass curve matches pointwise `branch_bound` solves at every
//!   knot (and between knots) on randomized chains, single- AND
//!   multi-constraint — per-tau IP solves remain only as this oracle;
//! * `Planner::frontier` (parametric) reproduces the bisection sweep it
//!   replaced on the demo model, knot for knot;
//! * curves are bit-identical at 1 vs N threads;
//! * NaN/negative taus are rejected with errors (never panics), and
//!   degenerate cost tables no longer destabilize the greedy/hull sorts.

use ampq::coordinator::Strategy;
use ampq::exec::{ExecCfg, ExecPool};
use ampq::metrics::Objective;
use ampq::plan::demo::demo_model;
use ampq::plan::{Engine, PlanRequest, PlanService, ServeRequest};
use ampq::solver::problem::gen::{random, random_multi};
use ampq::solver::{branch_bound, dp, greedy, parametric, Mckp};
use ampq::util::Rng;

fn demo_planner(threads: usize) -> ampq::plan::Planner {
    let (graph, qlayers, calibration) = demo_model(2, 7);
    let mut engine = Engine::new().with_threads(threads);
    engine.register_synthetic("demo", graph, qlayers, calibration);
    engine.planner("demo").unwrap()
}

/// Pointwise branch & bound at an explicit primary budget — the oracle the
/// parametric sweep must match.
fn solve_at(p: &Mckp, primary_budget: f64) -> ampq::solver::Solution {
    let mut q = p.clone();
    q.budgets[0] = primary_budget;
    branch_bound::solve(&q)
}

#[test]
fn one_pass_curve_matches_pointwise_branch_bound_single_constraint() {
    let mut rng = Rng::new(0x515E_CA11);
    for trial in 0..120 {
        let p = random(&mut rng, 6, 5);
        let curve = parametric::frontier(&p);
        assert!(curve.exact, "trial {trial}: single-constraint sweeps are exact");
        assert!(!curve.is_empty(), "trial {trial}");
        for (i, pt) in curve.points.iter().enumerate() {
            // At the knot's own budget the oracle agrees...
            let s = solve_at(&p, pt.cost());
            assert!(s.feasible, "trial {trial} knot {i}");
            assert!(
                (s.gain - pt.gain).abs() < 1e-9,
                "trial {trial} knot {i}: parametric {} vs oracle {}",
                pt.gain,
                s.gain
            );
            // ...and just below the NEXT knot nothing better appears.
            if let Some(next) = curve.points.get(i + 1) {
                let mid = 0.5 * (pt.cost() + next.cost());
                let m = solve_at(&p, mid);
                assert!(
                    (m.gain - pt.gain).abs() < 1e-9,
                    "trial {trial} knot {i}: mid-budget gain {} vs knot {}",
                    m.gain,
                    pt.gain
                );
            }
        }
    }
}

#[test]
fn one_pass_curve_matches_pointwise_branch_bound_multi_constraint() {
    let mut rng = Rng::new(0x9A55_0A11);
    for trial in 0..120 {
        let dims = 2 + (trial % 2);
        let p = random_multi(&mut rng, 4, 4, dims);
        let mut curve = parametric::frontier(&p);
        if !curve.exact {
            curve = parametric::harden_with(&p, curve, &ExecPool::sequential());
        }
        let exact = p.brute_force();
        if curve.is_empty() {
            assert!(!exact.feasible, "trial {trial}: empty curve on a feasible instance");
            continue;
        }
        assert!(exact.feasible, "trial {trial}");
        let top = curve.points.last().unwrap();
        assert!(
            (top.gain - exact.gain).abs() < 1e-9,
            "trial {trial}: top knot {} vs brute force {}",
            top.gain,
            exact.gain
        );
        for (i, pt) in curve.points.iter().enumerate() {
            let s = solve_at(&p, pt.cost());
            assert!(s.feasible, "trial {trial} knot {i}");
            assert!(
                (s.gain - pt.gain).abs() < 1e-9,
                "trial {trial} knot {i}: parametric {} vs oracle {}",
                pt.gain,
                s.gain
            );
        }
    }
}

#[test]
fn curves_are_bit_identical_at_one_vs_n_threads() {
    let mut rng = Rng::new(0x7_BEAD);
    let pools = [
        ExecPool::sequential(),
        ExecPool::new(ExecCfg::new(4)),
        ExecPool::new(ExecCfg::new(8)),
    ];
    for trial in 0..30 {
        let dims = 1 + (trial % 3 == 0) as usize;
        let p = random_multi(&mut rng, 9, 6, dims);
        let base = parametric::frontier_with(&p, &pools[0]);
        for pool in &pools[1..] {
            assert_eq!(base, parametric::frontier_with(&p, pool), "trial {trial}");
        }
    }
    // And end to end through the Planner (assert_eq: every knot bit-equal).
    let f1 = demo_planner(1).frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
    let f8 = demo_planner(8).frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
    assert_eq!(f1, f8);
}

#[test]
fn planner_frontier_reproduces_the_bisection_sweep_on_demo() {
    let planner = demo_planner(1);
    for objective in [Objective::EmpiricalTime, Objective::Memory] {
        let parametric_f = planner.frontier(objective, Strategy::Ip).unwrap();
        let bisection_f = planner.frontier_via_bisection(objective, Strategy::Ip).unwrap();
        // Every gain level the bisection sweep localized appears on the
        // one-pass curve.
        for (i, old) in bisection_f.points.iter().enumerate() {
            let hit = parametric_f
                .points
                .iter()
                .find(|p| (p.gain - old.gain).abs() <= 1e-9)
                .unwrap_or_else(|| {
                    panic!(
                        "{objective:?} knot {i} (gain {}) missing from the parametric curve",
                        old.gain
                    )
                });
            // The parametric knot is the CHEAPEST config at its gain level;
            // the bisection record carries whatever the pointwise solve
            // happened to pick, so its MSE can only be >= (equal on the
            // tie-free empirical-time family, where configs match too).
            assert!(
                hit.predicted_mse <= old.predicted_mse + 1e-12,
                "{objective:?} knot {i}: parametric mse {} above bisection {}",
                hit.predicted_mse,
                old.predicted_mse
            );
            if objective == Objective::EmpiricalTime {
                assert!(
                    (hit.predicted_mse - old.predicted_mse).abs() <= 1e-12,
                    "{objective:?} knot {i}: mse {} vs {}",
                    hit.predicted_mse,
                    old.predicted_mse
                );
                assert_eq!(hit.config, old.config, "{objective:?} knot {i}");
            }
        }
        // The parametric curve can only be FINER (it is exact), and its
        // step function dominates the bisection curve's everywhere.
        assert!(parametric_f.len() >= bisection_f.len());
        let n = 400;
        for i in 0..=n {
            let tau = parametric_f.tau_max * i as f64 / n as f64;
            let a = parametric_f.at(tau);
            let b = bisection_f.at(tau);
            assert!(
                a.gain + 1e-9 >= b.gain,
                "{objective:?} tau {tau}: parametric {} below bisection {}",
                a.gain,
                b.gain
            );
        }
    }
}

#[test]
fn planner_frontier_matches_pointwise_solves_at_every_knot() {
    let planner = demo_planner(1);
    let f = planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
    assert!(f.len() > 3, "demo frontier should have several knots");
    // Probe each knot's own tau plus a point just below the next knot.
    let mut taus: Vec<f64> = Vec::new();
    for w in f.points.windows(2) {
        taus.push(w[1].tau);
        taus.push(0.5 * (w[0].tau.max(1e-9) + w[1].tau));
    }
    taus.push(f.tau_max);
    for &tau in &taus {
        let point = f.at(tau);
        let plan = planner
            .solve(&PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau))
            .unwrap();
        assert!(
            (point.gain - plan.gain).abs() < 1e-9,
            "tau {tau}: frontier {} vs pointwise {}",
            point.gain,
            plan.gain
        );
        assert_eq!(point.config, plan.config, "tau {tau}");
    }
}

#[test]
fn nan_taus_error_instead_of_panicking() {
    let (graph, qlayers, calibration) = demo_model(1, 3);
    let mut engine = Engine::new();
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let svc = PlanService::from_engine(&mut engine, &["demo"]).unwrap();

    for bad in [f64::NAN, f64::INFINITY, -0.004] {
        // Direct solves reject at the request boundary.
        let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(bad);
        assert!(svc.solve("demo", &req).is_err(), "tau {bad} must be rejected");
        // Frontier lookups reject per request — the batch completes with an
        // error for the offending entry instead of a poisoned process.
        let lookup = ServeRequest::new("demo", req).via_frontier();
        assert!(svc.answer(&lookup).is_err(), "tau {bad} lookup must error");
        let good = ServeRequest::new(
            "demo",
            PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004),
        );
        let batch = vec![good.clone(), lookup.clone(), good];
        let out = svc.serve_batch(&batch, &ExecPool::new(ExecCfg::new(4)));
        assert!(out.is_err(), "tau {bad} batch must surface the error");
    }
    // A NaN probing an already-built frontier resolves to the fallback
    // point (total lookup), not a panic.
    let f = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
    assert_eq!(f.at(f64::NAN).gain, f.points[0].gain);
}

#[test]
fn degenerate_cost_tables_survive_every_solver() {
    // Equal-cost and denormal-step tables: hull/greedy sorts are total,
    // branch & bound keeps its bound sound, the parametric curve matches
    // the oracle.
    let cases = vec![
        Mckp::new(
            vec![vec![0.0, 3.0, 7.0], vec![0.0, 4.0]],
            vec![vec![1.0, 1.0, 1.0], vec![0.0, 2.0]],
            3.5,
        )
        .unwrap(),
        Mckp::new(
            vec![vec![0.0, 5.0, 10.0], vec![0.0, 1.0]],
            vec![vec![0.0, 1e-300, 2e-300], vec![0.0, 1.0]],
            0.5,
        )
        .unwrap(),
        Mckp::new(
            vec![vec![0.0, 2.0], vec![0.0, 9.0], vec![1.0, 1.0]],
            vec![vec![0.0, 0.0], vec![0.0, 5.0], vec![2.0, 2.0]],
            2.0,
        )
        .unwrap(),
    ];
    for (i, p) in cases.iter().enumerate() {
        let exact = p.brute_force();
        let bb = branch_bound::solve(p);
        assert_eq!(bb.feasible, exact.feasible, "case {i}");
        if exact.feasible {
            assert!(
                (bb.gain - exact.gain).abs() < 1e-9,
                "case {i}: bb {} vs {}",
                bb.gain,
                exact.gain
            );
        }
        let g = greedy::solve(p);
        assert!(g.gain <= exact.gain + 1e-9, "case {i}");
        let d = dp::solve(p);
        assert!(d.gain <= exact.gain + 1e-9, "case {i}");
        // Knot gains never overstate the oracle.  (No equality here: with
        // sub-EPS cost gaps the pointwise solver's EPS budget slack can
        // legitimately reach the NEXT knot, so the oracle may exceed a
        // knot that sits within EPS of a better one.)
        let curve = parametric::frontier(p);
        for pt in &curve.points {
            let s = solve_at(p, pt.cost());
            assert!(
                s.feasible && s.gain >= pt.gain - 1e-9,
                "case {i}: oracle {} below knot {}",
                s.gain,
                pt.gain
            );
        }
        if exact.feasible {
            let top = curve.points.last().unwrap();
            assert!((top.gain - exact.gain).abs() < 1e-9, "case {i}");
        }
    }
}

/// Solver-oracle fuzz: many small randomized MCKP instances with fixed
/// seeds, every solver checked against `brute_force`.  Run by the CI fuzz
/// job (`cargo test --release --test parametric -- --ignored fuzz`).
#[test]
#[ignore = "fuzz job: CI runs it with --ignored (slow under the default profile)"]
fn fuzz_solver_oracle_small_instances() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(0xF025 ^ seed);
        for trial in 0..60 {
            let single = trial % 2 == 0;
            let p = if single {
                random(&mut rng, 5, 5)
            } else {
                random_multi(&mut rng, 4, 4, 2)
            };
            let exact = p.brute_force();
            let bb = branch_bound::solve(&p);
            assert_eq!(bb.feasible, exact.feasible, "seed {seed} trial {trial}");
            if exact.feasible {
                assert!(
                    (bb.gain - exact.gain).abs() < 1e-9,
                    "seed {seed} trial {trial}: bb {} vs brute {}",
                    bb.gain,
                    exact.gain
                );
            }
            let g = greedy::solve(&p);
            if g.feasible {
                assert!(p.fits(&g.costs), "seed {seed} trial {trial}: greedy infeasible");
                assert!(
                    g.gain <= exact.gain + 1e-9,
                    "seed {seed} trial {trial}: greedy {} beats brute {}",
                    g.gain,
                    exact.gain
                );
            }
            if single {
                let d = dp::solve(&p);
                assert_eq!(d.feasible, exact.feasible, "seed {seed} trial {trial}");
                if d.feasible {
                    assert!(d.cost <= p.budget() + 1e-9, "seed {seed} trial {trial}");
                }
            }
            let mut curve = parametric::frontier(&p);
            if !curve.exact {
                curve = parametric::harden_with(&p, curve, &ExecPool::sequential());
            }
            if curve.is_empty() {
                assert!(!exact.feasible, "seed {seed} trial {trial}: empty curve");
                continue;
            }
            for pt in &curve.points {
                let s = solve_at(&p, pt.cost());
                assert!(
                    s.feasible && (s.gain - pt.gain).abs() < 1e-9,
                    "seed {seed} trial {trial}: knot {} vs oracle {}",
                    pt.gain,
                    s.gain
                );
            }
            if exact.feasible {
                let top = curve.points.last().unwrap();
                assert!(
                    (top.gain - exact.gain).abs() < 1e-9,
                    "seed {seed} trial {trial}: top {} vs brute {}",
                    top.gain,
                    exact.gain
                );
            }
        }
    }
}
