//! Parallel-vs-sequential equivalence: the exec layer's determinism
//! contract, property-tested end to end.
//!
//! Every parallelized path — the branch & bound solver, `Planner::frontier`
//! (the parametric chain DP's state merge), the Engine's
//! Calibrated/Measured stage fan-outs, planner sweeps, and
//! `PlanService::serve_batch` — must produce BIT-IDENTICAL
//! output at `threads = 1` and `threads = N`.  These tests compare the
//! full artifacts with `assert_eq!` (no tolerances): any scheduling leak
//! into the numbers is a failure.

use ampq::coordinator::Strategy;
use ampq::exec::{ExecCfg, ExecPool};
use ampq::metrics::Objective;
use ampq::plan::demo::demo_model;
use ampq::plan::{Engine, PlanRequest, PlanService, ServeRequest};
use ampq::solver::problem::gen::random_multi;
use ampq::solver::solve_with;
use ampq::util::Rng;

fn pools() -> [ExecPool; 3] {
    [
        ExecPool::sequential(),
        ExecPool::new(ExecCfg::new(3)),
        ExecPool::new(ExecCfg::new(8)),
    ]
}

#[test]
fn branch_bound_is_thread_count_invariant() {
    // Seeded random MCKP instances, single and multi constraint, across a
    // size range that straddles the solver's decomposition threshold.
    let mut rng = Rng::new(0x5EED);
    let [seq, p3, p8] = pools();
    for trial in 0..60 {
        let dims = 1 + (trial % 3 == 0) as usize;
        let p = random_multi(&mut rng, 12, 8, dims);
        let base = solve_with(&p, &seq);
        assert_eq!(base, solve_with(&p, &p3), "trial {trial} (3 threads)");
        assert_eq!(base, solve_with(&p, &p8), "trial {trial} (8 threads)");
    }
}

fn demo_engine(threads: usize, blocks: usize, seed: u64) -> Engine {
    let (graph, qlayers, calibration) = demo_model(blocks, seed);
    let mut engine = Engine::new().with_threads(threads);
    engine.register_synthetic("demo", graph, qlayers, calibration);
    engine
}

#[test]
fn stage_artifacts_are_thread_count_invariant() {
    for (blocks, seed) in [(1, 3), (2, 7)] {
        let mut seq = demo_engine(1, blocks, seed);
        let mut par = demo_engine(8, blocks, seed);
        assert_eq!(
            seq.partitioned("demo").unwrap(),
            par.partitioned("demo").unwrap(),
            "Partitioned artifact diverged"
        );
        assert_eq!(
            seq.calibrated("demo").unwrap(),
            par.calibrated("demo").unwrap(),
            "Calibrated artifact diverged"
        );
        // Measured carries the simulator's NOISY gain tables: equality here
        // proves the per-measurement RNG streams line up exactly.
        assert_eq!(
            seq.measured("demo").unwrap(),
            par.measured("demo").unwrap(),
            "Measured artifact diverged"
        );
    }
}

#[test]
fn plans_and_sweeps_are_thread_count_invariant() {
    let seq = demo_engine(1, 2, 7).planner("demo").unwrap();
    let par = demo_engine(8, 2, 7).planner("demo").unwrap();
    let taus = [0.0, 0.001, 0.004, 0.007];
    for objective in Objective::ALL {
        for &tau in &taus {
            let req = PlanRequest::new(objective).with_loss_budget(tau);
            assert_eq!(seq.solve(&req).unwrap(), par.solve(&req).unwrap());
        }
    }
    let a = seq.sweep(&Objective::ALL, &Strategy::ALL, &taus, 1).unwrap();
    let b = par.sweep(&Objective::ALL, &Strategy::ALL, &taus, 1).unwrap();
    assert_eq!(a, b);
}

#[test]
fn frontiers_are_thread_count_invariant() {
    let seq = demo_engine(1, 2, 7).planner("demo").unwrap();
    let par = demo_engine(6, 2, 7).planner("demo").unwrap();
    for objective in Objective::ALL {
        let f1 = seq.frontier(objective, Strategy::Ip).unwrap();
        let fn_ = par.frontier(objective, Strategy::Ip).unwrap();
        assert_eq!(f1, fn_, "{objective:?} frontier diverged");
        // And the curve still matches pointwise solves.  (Tolerance, not
        // bits: the parametric curve and the pointwise solver may pick
        // different members of an exactly-tied optimum — the demo's blocks
        // are structurally identical under IP-TT — whose float sums can
        // differ by an ulp.)
        for &tau in &[0.001, 0.004] {
            let plan = seq
                .solve(&PlanRequest::new(objective).with_loss_budget(tau))
                .unwrap();
            let g = f1.at(tau).gain;
            assert!(
                (g - plan.gain).abs() <= 1e-9 * (1.0 + plan.gain.abs()),
                "{objective:?} tau {tau}: frontier {g} vs pointwise {}",
                plan.gain
            );
        }
    }
}

#[test]
fn serve_batches_are_thread_count_invariant() {
    let mut engine = demo_engine(4, 2, 7);
    let svc = PlanService::from_engine(&mut engine, &["demo"]).unwrap();
    let reqs: Vec<ServeRequest> = [0.001, 0.002, 0.004, 0.006]
        .iter()
        .flat_map(|&tau| {
            vec![
                ServeRequest::new(
                    "demo",
                    PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau),
                ),
                ServeRequest::new(
                    "demo",
                    PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau),
                )
                .via_frontier(),
                ServeRequest::new(
                    "demo",
                    PlanRequest::new(Objective::Memory).with_loss_budget(tau),
                ),
            ]
        })
        .collect();
    let [seq, p3, p8] = pools();
    let base = svc.serve_batch(&reqs, &seq).unwrap();
    assert_eq!(base, svc.serve_batch(&reqs, &p3).unwrap());
    assert_eq!(base, svc.serve_batch(&reqs, &p8).unwrap());
}

#[test]
fn engine_threads_do_not_thrash_the_disk_cache() {
    // A parallel engine and a sequential engine sharing one cache dir must
    // agree on the cached bytes: the second staging loads, not recomputes.
    let cache = std::env::temp_dir()
        .join(format!("ampq_parallel_cache_{}", std::process::id()));
    std::fs::remove_dir_all(&cache).ok();
    let (graph, qlayers, calibration) = demo_model(2, 7);

    let mut par = Engine::new().with_threads(8).with_cache_dir(&cache);
    par.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
    let staged = par.planner("demo").unwrap();

    let mut seq = Engine::new().with_threads(1).with_cache_dir(&cache);
    seq.register_synthetic("demo", graph, qlayers, calibration);
    let loaded = seq.planner("demo").unwrap();
    assert_eq!(seq.counters().measurement_passes, 0, "cache must hit");
    assert_eq!(seq.counters().calibration_passes, 0, "cache must hit");

    let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004);
    assert_eq!(staged.solve(&req).unwrap(), loaded.solve(&req).unwrap());

    std::fs::remove_dir_all(&cache).ok();
}
