//! Acceptance suite for the backend subsystem (pluggable device profiles):
//!
//! * `DeviceProfile` JSON round-trips exactly, including randomized
//!   property-style profiles;
//! * the `gaudi2` built-in reproduces the pre-backend simulator TTFTs
//!   bit-for-bit under a fixed seed;
//! * cross-device behaviour: `cpu-roofline` yields ~zero fp8 time gain
//!   while `gaudi2` does not, and the four built-ins produce distinct
//!   Pareto frontiers;
//! * a profile loaded from a user JSON file plans end-to-end;
//! * Measured stage artifacts cache per device without collisions.

use ampq::backend::{DeviceProfile, RateTable, Registry};
use ampq::coordinator::Strategy;
use ampq::gaudisim::{HwModel, MpConfig, Simulator};
use ampq::metrics::Objective;
use ampq::numerics::Format;
use ampq::plan::demo::demo_model;
use ampq::plan::{Engine, PlanRequest};
use ampq::util::{Json, Rng};
use std::path::PathBuf;

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ampq_backend_{tag}_{}", std::process::id()))
}

fn random_profile(rng: &mut Rng, i: usize) -> DeviceProfile {
    let mut rates = RateTable::uniform(1.0);
    for f in Format::ALL {
        if f != Format::Bf16 {
            rates.set(f, 0.25 + rng.f64() * 4.0);
        }
    }
    let supported: Vec<Format> = Format::ALL
        .iter()
        .copied()
        .filter(|f| *f == Format::Bf16 || rng.bool())
        .collect();
    DeviceProfile {
        name: format!("rand-{i}"),
        n_mme: 1 + rng.below(8),
        n_tpc: 1 + rng.below(8),
        mme_macs_per_us: 1_000.0 + rng.f64() * 500_000.0,
        tpc_bytes_per_us: 1_000.0 + rng.f64() * 50_000.0,
        hbm_bytes_per_us: 10_000.0 + rng.f64() * 100_000.0,
        launch_us: rng.f64() * 10.0,
        noise_std: rng.f64() * 0.05,
        enable_fusion: rng.bool(),
        mme_rates: rates,
        supported,
        hbm_capacity_bytes: rng.f64() * 1.0e12,
    }
}

#[test]
fn profile_json_roundtrip_property() {
    let mut rng = Rng::new(0xBACC);
    for i in 0..64 {
        let p = random_profile(&mut rng, i);
        p.validate().unwrap();
        let text = p.to_json().to_string();
        let back = DeviceProfile::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, p, "round-trip mismatch for {}", p.name);
        // Double round-trip is a fixed point.
        assert_eq!(back.to_json().to_string(), text);
    }
}

#[test]
fn registry_builtins_are_valid_and_distinct() {
    let r = Registry::builtin();
    let names = r.names();
    assert_eq!(names, vec!["cpu-roofline", "gaudi2", "gaudi3", "generic-gpu"]);
    let mut base_rates = Vec::new();
    for p in r.iter() {
        p.validate().unwrap();
        assert!(p.supports(Format::Bf16));
        assert!(p.supports(Format::Fp8E4m3), "{}: paper menu must run", p.name);
        base_rates.push((p.name.clone(), p.mme_macs_per_us, p.n_mme));
    }
    base_rates.dedup_by(|a, b| a.1 == b.1 && a.2 == b.2);
    assert_eq!(base_rates.len(), 4, "built-ins must be architecturally distinct");
}

#[test]
fn gaudi2_profile_reproduces_legacy_ttfts_bit_for_bit() {
    // The acceptance criterion: planning on the gaudi2 built-in is the
    // identical computation the pre-backend HwModel::default() ran.
    let (graph, _, _) = demo_model(2, 3);
    let legacy = Simulator::new(&graph, HwModel::default());
    let gaudi2 = Registry::builtin().get("gaudi2").unwrap();
    let profiled = Simulator::for_device(&graph, &gaudi2);
    let nq = graph.qlayers.len();
    let mut mixed = MpConfig::all_bf16(nq);
    for l in (0..nq).step_by(3) {
        mixed.set(l, Format::Fp8E4m3);
    }
    for cfg in [
        MpConfig::all_bf16(nq),
        MpConfig::uniform(nq, Format::Fp8E4m3),
        mixed,
    ] {
        assert_eq!(legacy.makespan(&cfg), profiled.makespan(&cfg));
        // Noisy measurement with the same seed: bit-identical streams.
        let mut r1 = Rng::new(0x714e33);
        let mut r2 = Rng::new(0x714e33);
        assert_eq!(
            legacy.measure_ttft(&cfg, &mut r1, 5),
            profiled.measure_ttft(&cfg, &mut r2, 5)
        );
    }
}

#[test]
fn cpu_roofline_has_no_fp8_time_gain_but_gaudi2_does() {
    let (graph, _, _) = demo_model(2, 3);
    let nq = graph.qlayers.len();
    let bf16 = MpConfig::all_bf16(nq);
    let fp8 = MpConfig::uniform(nq, Format::Fp8E4m3);
    let registry = Registry::builtin();

    let gaudi = Simulator::for_device(&graph, &registry.get("gaudi2").unwrap());
    let g_base = gaudi.makespan(&bf16);
    let g_gain = g_base - gaudi.makespan(&fp8);
    assert!(g_gain / g_base > 0.05, "gaudi2 fp8 gain {g_gain} of {g_base} too small");

    let cpu = Simulator::for_device(&graph, &registry.get("cpu-roofline").unwrap());
    let c_base = cpu.makespan(&bf16);
    let c_gain = c_base - cpu.makespan(&fp8);
    assert!(
        c_gain.abs() / c_base < 0.01,
        "cpu-roofline fp8 gain {c_gain} of {c_base} should be ~zero"
    );
}

#[test]
fn four_builtins_produce_four_distinct_frontiers() {
    // The `ampq compare` acceptance path, engine-level: same model, four
    // devices, four different Pareto curves.
    let registry = Registry::builtin();
    let mut max_gains = Vec::new();
    for name in ["gaudi2", "gaudi3", "generic-gpu", "cpu-roofline"] {
        let (graph, qlayers, calibration) = demo_model(2, 7);
        let mut engine = Engine::new().with_device(registry.get(name).unwrap());
        engine.register_synthetic("demo", graph, qlayers, calibration);
        let planner = engine.planner("demo").unwrap();
        let frontier = planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
        let max_gain = frontier.points.last().unwrap().gain;
        max_gains.push((name, max_gain));
    }
    // cpu-roofline's time frontier is (near) flat; the others are not.
    let cpu = max_gains.iter().find(|(n, _)| *n == "cpu-roofline").unwrap().1;
    for (name, g) in &max_gains {
        if *name != "cpu-roofline" {
            assert!(*g > 10.0 * cpu.max(1e-9), "{name} frontier should dominate cpu");
        }
    }
    // All four max gains are pairwise distinct (different hardware).
    for i in 0..max_gains.len() {
        for j in (i + 1)..max_gains.len() {
            let (na, a) = &max_gains[i];
            let (nb, b) = &max_gains[j];
            assert!(
                (a - b).abs() > 1e-6 * (1.0 + a.abs()),
                "{na} and {nb} produced identical frontiers"
            );
        }
    }
}

#[test]
fn user_json_profile_plans_end_to_end() {
    let dir = temp_dir("userjson");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("my-accel.json");
    // A made-up accelerator: 3x fp8 MACs, modest bandwidth, no e5m2.
    let mut custom = DeviceProfile::gaudi2();
    custom.name = "my-accel".into();
    custom.mme_rates.set(Format::Fp8E4m3, 3.0);
    custom.supported =
        vec![Format::Fp32, Format::Fp16, Format::Bf16, Format::Fp8E4m3];
    std::fs::write(&path, custom.to_json().to_string()).unwrap();

    let mut registry = Registry::builtin();
    let name = registry.load(&path).unwrap();
    assert_eq!(name, "my-accel");

    let (graph, qlayers, calibration) = demo_model(2, 7);
    let mut engine = Engine::new().with_device(registry.get("my-accel").unwrap());
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let planner = engine.planner("demo").unwrap();
    let plan = planner
        .solve(
            &PlanRequest::new(Objective::EmpiricalTime)
                .with_loss_budget(0.004)
                .with_device("my-accel"),
        )
        .unwrap();
    assert_eq!(plan.device, "my-accel");
    assert!(plan.feasible);
    // Plan JSON round-trips with the device stamp intact.
    let back = ampq::plan::Plan::from_json(&Json::parse(&plan.to_json().to_string()).unwrap())
        .unwrap();
    assert_eq!(back, plan);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn per_device_measured_caches_do_not_collide() {
    let cache = temp_dir("cachesep");
    std::fs::remove_dir_all(&cache).ok();
    let (graph, qlayers, calibration) = demo_model(2, 7);
    let registry = Registry::builtin();

    let mut gains = Vec::new();
    for name in ["gaudi2", "gaudi3"] {
        let mut engine = Engine::new()
            .with_cache_dir(&cache)
            .with_device(registry.get(name).unwrap());
        engine.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
        let plan = engine
            .planner("demo")
            .unwrap()
            .solve(&PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004))
            .unwrap();
        assert_eq!(engine.counters().measurement_passes, 1, "{name} must measure");
        gains.push(plan.gain);
        assert!(cache.join("demo").join(format!("measured-{name}.json")).exists());
    }
    // Different hardware, different optimal gains.
    assert!((gains[0] - gains[1]).abs() > 1e-9);

    // Second pass per device: everything from cache, same answers.
    for (i, name) in ["gaudi2", "gaudi3"].iter().enumerate() {
        let mut engine = Engine::new()
            .with_cache_dir(&cache)
            .with_device(registry.get(name).unwrap());
        engine.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
        let plan = engine
            .planner("demo")
            .unwrap()
            .solve(&PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004))
            .unwrap();
        assert_eq!(engine.counters().measurement_passes, 0, "{name} must hit cache");
        assert_eq!(plan.gain, gains[i]);
    }
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn supported_mask_collapses_the_menu() {
    // A device without fp8: the paper menu collapses to [bf16] and every
    // strategy plans the all-baseline config even at generous budgets.
    let mut nofp8 = DeviceProfile::gaudi2();
    nofp8.name = "nofp8".into();
    nofp8.supported = vec![Format::Fp32, Format::Fp16, Format::Bf16];
    let (graph, qlayers, calibration) = demo_model(2, 7);
    let mut engine = Engine::new().with_device(nofp8);
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let planner = engine.planner("demo").unwrap();
    for strategy in Strategy::ALL {
        let plan = planner
            .solve(
                &PlanRequest::new(Objective::EmpiricalTime)
                    .with_strategy(strategy)
                    .with_loss_budget(0.007),
            )
            .unwrap();
        assert_eq!(plan.config.n_quantized(), 0, "{strategy:?}");
    }
}
