//! Acceptance suite for the 0.3 query redesign (issue acceptance criteria):
//!
//! * `Planner::frontier(EmpiricalTime, Ip).at(tau)` returns a plan whose
//!   config, gain, and predicted MSE equal a pointwise `Strategy::Ip` solve
//!   at that tau on the demo model;
//! * a two-constraint request (loss-MSE + memory cap) returns a plan
//!   satisfying both budgets and matching `brute_force` on a small instance;
//! * device-scoped requests resolve per-device (backend subsystem);
//! * `PlanService` answers concurrent plan/frontier queries with exactly one
//!   frontier sweep and thread-order-independent results.

use ampq::coordinator::{paper_tau_grid, Strategy};
use ampq::metrics::Objective;
use ampq::plan::demo::demo_model;
use ampq::plan::{Engine, Frontier, PlanRequest, ServeRequest};
use ampq::solver::{self, CostDim, Mckp};
use ampq::util::Json;

fn demo_engine() -> Engine {
    let (graph, qlayers, calibration) = demo_model(2, 7);
    let mut engine = Engine::new();
    engine.register_synthetic("demo", graph, qlayers, calibration);
    engine
}

#[test]
fn frontier_at_matches_pointwise_ip_solve() {
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    let frontier = planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
    assert!(frontier.points.len() > 3, "demo frontier should have several steps");
    for &tau in &paper_tau_grid() {
        let point = frontier.at(tau);
        let plan = planner
            .solve(&PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau))
            .unwrap();
        assert!(
            (point.gain - plan.gain).abs() < 1e-9,
            "tau {tau}: frontier gain {} vs pointwise {}",
            point.gain,
            plan.gain
        );
        assert!(
            (point.predicted_mse - plan.predicted_mse).abs() < 1e-15,
            "tau {tau}: frontier mse {} vs pointwise {}",
            point.predicted_mse,
            plan.predicted_mse
        );
        assert_eq!(point.config, plan.config, "tau {tau}: configs differ");
        assert_eq!(frontier.feasible_at(tau), plan.feasible, "tau {tau}");
    }
}

#[test]
fn frontier_is_monotone_and_pareto() {
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    for objective in Objective::ALL {
        let f = planner.frontier(objective, Strategy::Ip).unwrap();
        for w in f.points.windows(2) {
            assert!(w[1].predicted_mse > w[0].predicted_mse, "{objective:?}: mse not increasing");
            assert!(w[1].gain > w[0].gain, "{objective:?}: gain not increasing");
        }
        // at() is monotone in tau over a dense sweep.
        let mut last = f64::MIN;
        let n = 200;
        for i in 0..=n {
            let tau = f.tau_max * i as f64 / n as f64;
            let g = f.at(tau).gain;
            assert!(g >= last - 1e-12, "{objective:?} tau {tau}: {g} < {last}");
            last = g;
        }
    }
}

#[test]
fn frontier_json_roundtrip() {
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    let f = planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
    let text = f.to_json().to_string();
    let back = Frontier::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, f);
    // at() answers identically after the round-trip.
    for &tau in &paper_tau_grid() {
        assert_eq!(back.at(tau), f.at(tau));
    }
}

#[test]
fn two_constraint_request_satisfies_both_budgets() {
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    let free = planner
        .solve(&PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.007))
        .unwrap();
    let bf16_total: f64 = planner
        .partitioned()
        .qlayers
        .iter()
        .map(|q| q.params as f64 * 2.0)
        .sum();
    assert!(free.weight_bytes < bf16_total, "tau 0.007 must quantize something");

    // A cap just above the loss-optimal plan's bytes (and well below the
    // all-BF16 total): the solver runs the genuine two-dimension path and
    // must satisfy BOTH budgets without giving up gain.
    let cap = free.weight_bytes * 1.02;
    assert!(cap < bf16_total);
    let capped = planner
        .solve(
            &PlanRequest::new(Objective::EmpiricalTime)
                .with_loss_budget(0.007)
                .with_memory_cap(cap),
        )
        .unwrap();
    assert!(capped.feasible, "two-constraint demo request must be satisfiable");
    assert!(
        capped.predicted_mse <= capped.budget + 1e-12,
        "loss budget violated: {} > {}",
        capped.predicted_mse,
        capped.budget
    );
    assert!(
        capped.weight_bytes <= cap + 1e-9,
        "memory cap violated: {} > {cap}",
        capped.weight_bytes
    );
    assert_eq!(capped.memory_cap, Some(cap));
    assert!((capped.gain - free.gain).abs() < 1e-9, "a satisfied cap must not cost gain");
    // And the plan round-trips with the cap recorded.
    let back =
        ampq::plan::Plan::from_json(&Json::parse(&capped.to_json().to_string()).unwrap()).unwrap();
    assert_eq!(back, capped);

    // A cap below the all-FP8 floor is jointly unsatisfiable: the planner
    // reports the fallback instead of silently violating a budget.
    let floor: f64 = planner.partitioned().qlayers.iter().map(|q| q.params as f64).sum();
    let impossible = planner
        .solve(
            &PlanRequest::new(Objective::EmpiricalTime)
                .with_loss_budget(0.007)
                .with_memory_cap(floor * 0.9),
        )
        .unwrap();
    assert!(!impossible.feasible);
}

#[test]
fn two_constraint_small_instance_matches_brute_force() {
    // The exact solver the request path uses (branch & bound over both
    // dimensions) against the exhaustive oracle on a hand-sized instance.
    let gains = vec![vec![0.0, 5.0], vec![0.0, 4.0], vec![0.0, 3.0]];
    let mse = vec![vec![0.0, 1.0], vec![0.0, 2.0], vec![0.0, 0.5]];
    let bytes = vec![vec![4.0, 2.0], vec![6.0, 3.0], vec![2.0, 1.0]];
    let p = Mckp::multi(
        gains,
        vec![CostDim::new("loss_mse", mse), CostDim::new("weight_bytes", bytes)],
        vec![2.0, 9.0],
    )
    .unwrap();
    let exact = p.brute_force();
    let got = solver::solve(&p);
    assert_eq!(got.feasible, exact.feasible);
    assert!((got.gain - exact.gain).abs() < 1e-9, "{} vs {}", got.gain, exact.gain);
    assert!(p.fits(&got.costs));
}

#[test]
fn device_scoped_requests_constrain_the_planner() {
    // A request carrying a device must resolve only against a planner
    // measured on that device; its Plan is stamped with the device name.
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    let base = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004);
    let plain = planner.solve(&base).unwrap();
    assert_eq!(plain.device, "gaudi2");
    let scoped = planner.solve(&base.clone().with_device("gaudi2")).unwrap();
    assert_eq!(scoped, plain);
    assert!(planner.solve(&base.with_device("cpu-roofline")).is_err());
}

#[test]
fn service_concurrent_queries_share_one_frontier() {
    let mut engine = demo_engine();
    let svc = engine.service(&["demo"]).unwrap();

    // Reference answers, computed sequentially on a clone (shared state).
    let taus = [0.001, 0.002, 0.003, 0.004, 0.005, 0.006, 0.007];
    let reference: Vec<ampq::plan::Plan> = taus
        .iter()
        .map(|&tau| {
            svc.solve(
                "demo",
                &PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau),
            )
            .unwrap()
        })
        .collect();

    let results: Vec<Vec<ampq::plan::Plan>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let svc = svc.clone();
                s.spawn(move || {
                    let mut out = Vec::new();
                    for &tau in &taus {
                        // Exercise both the solve path and the frontier cache.
                        let plan = svc
                            .solve(
                                "demo",
                                &PlanRequest::new(Objective::EmpiricalTime)
                                    .with_loss_budget(tau),
                            )
                            .unwrap();
                        let f = svc
                            .frontier("demo", Objective::EmpiricalTime, Strategy::Ip)
                            .unwrap();
                        assert_eq!(f.at(tau).config, plan.config);
                        out.push(plan);
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for thread_plans in &results {
        assert_eq!(thread_plans, &reference);
    }
    assert_eq!(svc.frontier_solves(), 1, "8 threads must share one frontier sweep");
}

#[test]
fn serve_batch_mixed_requests_end_to_end() {
    let mut engine = demo_engine();
    let svc = engine.service(&["demo"]).unwrap();
    let free = svc
        .solve(
            "demo",
            &PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.007),
        )
        .unwrap();
    let reqs = vec![
        ServeRequest::new(
            "demo",
            PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004),
        ),
        ServeRequest::new(
            "demo",
            PlanRequest::new(Objective::EmpiricalTime)
                .with_loss_budget(0.007)
                .with_memory_cap(free.weight_bytes * 0.95),
        ),
        ServeRequest::new(
            "demo",
            PlanRequest::new(Objective::Memory)
                .with_loss_budget(0.003)
                .with_strategy(Strategy::Prefix),
        ),
        ServeRequest::new(
            "demo",
            PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.002),
        )
        .via_frontier(),
    ];
    // Round-trip the batch through its JSON file format first.
    let file = Json::Arr(reqs.iter().map(|r| r.to_json()).collect()).to_string();
    let parsed = ampq::plan::load_requests(&Json::parse(&file).unwrap()).unwrap();
    assert_eq!(parsed, reqs);

    let sequential: Vec<Json> = reqs.iter().map(|r| svc.answer(r).unwrap()).collect();
    let parallel = svc
        .serve_batch(&parsed, &ampq::exec::ExecPool::new(ampq::exec::ExecCfg::new(3)))
        .unwrap();
    assert_eq!(parallel, sequential);

    // The frontier answer matches a pointwise solve at its tau.
    let pointwise = svc
        .solve(
            "demo",
            &PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.002),
        )
        .unwrap();
    let fr = &parallel[3];
    assert_eq!(fr.get("kind").unwrap().str().unwrap(), "frontier_point");
    assert!((fr.get("gain").unwrap().f64().unwrap() - pointwise.gain).abs() < 1e-9);
}
