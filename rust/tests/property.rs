//! Property-based tests (hand-rolled generator harness — proptest is not
//! vendored; failures print the offending seed for reproduction).
//!
//! Invariants:
//!   * partition: disjoint cover, qidx order, convergence on random
//!     series-parallel DAGs; groups non-overlapping in depth;
//!   * solvers: exact == brute force; greedy/dp feasible and <= exact;
//!     LP bound >= exact; budgets always respected;
//!   * simulator: determinism, monotonicity under quantization, group
//!     additivity on random sequential chains.

use ampq::gaudisim::{HwModel, MpConfig, Simulator};
use ampq::graph::partition::{partition, validate_sequential};
use ampq::graph::{Engine, Graph, Node};
use ampq::numerics::Format;
use ampq::solver::problem::gen::random_multi;
use ampq::solver::{branch_bound, dp, greedy, lp_relax, Mckp};
use ampq::util::Rng;

fn qnode(id: String, qidx: i32, macs: u64) -> Node {
    Node {
        id,
        kind: if qidx >= 0 { "linear".into() } else { "op".into() },
        engine: if qidx >= 0 { Engine::Mme } else { Engine::Tpc },
        qidx,
        macs,
        bytes_in: 4096,
        bytes_out: 4096,
        param_bytes: if qidx >= 0 { 8192 } else { 0 },
        c: 16,
        k: 16,
    }
}

/// Random series-parallel-ish DAG: a chain of stages, each either a single
/// node or a fan-out/fan-in diamond of 2-4 parallel quantizable nodes.
fn random_sp_graph(rng: &mut Rng) -> Graph {
    let mut nodes: Vec<Node> = vec![qnode("src".into(), -1, 0)];
    let mut edges: Vec<(usize, usize)> = Vec::new();
    let mut tail = 0usize;
    let mut qidx = 0i32;
    let stages = rng.range(1, 7);
    for s in 0..stages {
        if rng.bool() {
            // single quantizable node
            let v = nodes.len();
            nodes.push(qnode(format!("s{s}"), qidx, 1_000_000 + rng.below(4_000_000) as u64));
            qidx += 1;
            edges.push((tail, v));
            tail = v;
        } else {
            // diamond: fan out to w parallel nodes, merge at a quantizable
            // or pass-through node
            let w = rng.range(2, 5);
            let mut mids = Vec::new();
            for i in 0..w {
                let v = nodes.len();
                nodes.push(qnode(format!("s{s}b{i}"), qidx, 1_000_000 + rng.below(4_000_000) as u64));
                qidx += 1;
                edges.push((tail, v));
                mids.push(v);
            }
            let m = nodes.len();
            let merge_q = rng.bool();
            nodes.push(if merge_q {
                let n = qnode(format!("s{s}m"), qidx, 2_000_000);
                qidx += 1;
                n
            } else {
                qnode(format!("s{s}m"), -1, 0)
            });
            for v in mids {
                edges.push((v, m));
            }
            tail = m;
        }
    }
    let t = nodes.len();
    nodes.push(qnode("sink".into(), -1, 0));
    edges.push((tail, t));
    Graph::synthetic(nodes, edges)
}

#[test]
fn partition_invariants_on_random_dags() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed);
        let g = random_sp_graph(&mut rng);
        let p = partition(&g).unwrap_or_else(|e| panic!("seed {seed}: partition failed: {e}"));
        // Disjoint cover of all quantizable layers.
        let mut seen = vec![false; g.qlayers.len()];
        for gr in &p.groups {
            assert!(!gr.is_empty(), "seed {seed}: empty group");
            for &q in &gr.qidxs {
                assert!(!seen[q], "seed {seed}: qidx {q} duplicated");
                seen[q] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "seed {seed}: not covered");
        validate_sequential(&g, &p).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

#[test]
fn solver_cross_validation_random_instances() {
    for seed in 0..300u64 {
        let mut rng = Rng::new(1000 + seed);
        let p = random_mckp(&mut rng);
        let exact = p.brute_force();
        let bb = branch_bound::solve(&p);
        let d = dp::solve(&p);
        let gr = greedy::solve(&p);
        let lp = lp_relax::solve(&p);

        assert_eq!(bb.feasible, exact.feasible, "seed {seed}");
        if !exact.feasible {
            continue;
        }
        assert!((bb.gain - exact.gain).abs() < 1e-9, "seed {seed}: bb {} exact {}", bb.gain, exact.gain);
        assert!(bb.cost <= p.budget() + 1e-9, "seed {seed}");
        assert!(d.cost <= p.budget() + 1e-9, "seed {seed}");
        assert!(gr.cost <= p.budget() + 1e-9, "seed {seed}");
        assert!(d.gain <= exact.gain + 1e-9, "seed {seed}");
        assert!(gr.gain <= exact.gain + 1e-9, "seed {seed}");
        assert!(lp.bound >= exact.gain - 1e-9, "seed {seed}: lp {} exact {}", lp.bound, exact.gain);
    }
}

#[test]
fn multi_constraint_solver_cross_validation() {
    // On random multi-budget instances: branch & bound is exact against the
    // brute-force oracle (feasibility AND gain), greedy stays within every
    // budget and below exact, the Lagrangian LP bound dominates exact, and
    // the primary-dim DP never reports a solution violating the budgets it
    // can see.
    for seed in 0..300u64 {
        let mut rng = Rng::new(5000 + seed);
        let dims = 2 + (seed % 2) as usize;
        let p = random_multi(&mut rng, 4, 4, dims);
        let exact = p.brute_force();
        let bb = branch_bound::solve(&p);
        let gr = greedy::solve(&p);
        let lp = lp_relax::solve(&p);

        assert_eq!(bb.feasible, exact.feasible, "seed {seed}");
        assert_eq!(bb.costs.len(), dims, "seed {seed}");
        if gr.feasible {
            assert!(p.fits(&gr.costs), "seed {seed}: greedy violates a budget");
            assert!(gr.gain <= exact.gain + 1e-9, "seed {seed}");
        }
        if !exact.feasible {
            continue;
        }
        assert!(
            (bb.gain - exact.gain).abs() < 1e-9,
            "seed {seed}: bb {} exact {}",
            bb.gain,
            exact.gain
        );
        assert!(p.fits(&bb.costs), "seed {seed}: bb violates a budget");
        assert!(
            lp.bound >= exact.gain - 1e-9,
            "seed {seed}: lagrangian {} exact {}",
            lp.bound,
            exact.gain
        );
        // DP is a primary-dim heuristic on multi instances, but its
        // feasibility verdict must still be honest.
        let d = dp::solve(&p);
        if d.feasible {
            assert!(p.fits(&d.costs), "seed {seed}: dp feasibility lies");
        }
    }
}

fn random_mckp(rng: &mut Rng) -> Mckp {
    let j = rng.range(1, 6);
    let mut gains = Vec::new();
    let mut costs = Vec::new();
    for _ in 0..j {
        let k = rng.range(1, 6);
        gains.push((0..k).map(|_| rng.f64() * 10.0).collect::<Vec<f64>>());
        costs.push((0..k).map(|_| rng.f64() * 3.0).collect::<Vec<f64>>());
    }
    let lo: f64 = costs.iter().map(|c| c.iter().cloned().fold(f64::MAX, f64::min)).sum();
    let hi: f64 = costs.iter().map(|c| c.iter().cloned().fold(0.0f64, f64::max)).sum();
    let budget = lo + rng.f64() * (hi - lo).max(0.01);
    Mckp::new(gains, costs, budget).unwrap()
}

#[test]
fn simulator_invariants_on_random_dags() {
    let hw = HwModel { noise_std: 0.0, ..HwModel::default() };
    for seed in 0..60u64 {
        let mut rng = Rng::new(2000 + seed);
        let g = random_sp_graph(&mut rng);
        let nq = g.qlayers.len();
        if nq == 0 {
            continue;
        }
        let sim = Simulator::new(&g, hw.clone());
        let base_cfg = MpConfig::all_bf16(nq);
        let base = sim.makespan(&base_cfg);
        assert!(base > 0.0, "seed {seed}");
        // Determinism.
        assert_eq!(base, sim.makespan(&base_cfg), "seed {seed}");
        // Monotonicity: quantizing any single layer never slows things.
        for l in 0..nq {
            let mut c = MpConfig::all_bf16(nq);
            c.set(l, Format::Fp8E4m3);
            let t = sim.makespan(&c);
            assert!(t <= base * 1.01, "seed {seed} layer {l}: {t} > {base}");
        }
        // All-FP8 is at least as fast as any single-layer config.
        let full = sim.makespan(&MpConfig::uniform(nq, Format::Fp8E4m3));
        assert!(full <= base, "seed {seed}");
    }
}

#[test]
fn group_gain_additivity_on_random_dags() {
    // Per-group FP8 gains must sum to (approximately) the all-FP8 gain —
    // the paper's additivity claim, which holds by construction for
    // sequential sub-graphs (noise-free).
    let hw = HwModel { noise_std: 0.0, ..HwModel::default() };
    for seed in 0..60u64 {
        let mut rng = Rng::new(3000 + seed);
        let g = random_sp_graph(&mut rng);
        let nq = g.qlayers.len();
        if nq == 0 {
            continue;
        }
        let p = partition(&g).unwrap();
        let sim = Simulator::new(&g, hw.clone());
        let base = sim.makespan(&MpConfig::all_bf16(nq));
        let mut sum = 0.0;
        for gr in &p.groups {
            let mut c = MpConfig::all_bf16(nq);
            for &q in &gr.qidxs {
                c.set(q, Format::Fp8E4m3);
            }
            sum += base - sim.makespan(&c);
        }
        let all = base - sim.makespan(&MpConfig::uniform(nq, Format::Fp8E4m3));
        if all > 1.0 {
            let rel = (sum - all).abs() / all;
            assert!(rel < 0.10, "seed {seed}: sum {sum} vs all {all} (rel {rel})");
        }
    }
}

#[test]
fn mpconfig_label_roundtrip_random() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(4000 + seed);
        let n = rng.range(1, 40);
        let mut cfg = MpConfig::all_bf16(n);
        for l in 0..n {
            if rng.bool() {
                cfg.set(l, Format::Fp8E4m3);
            }
        }
        let label = cfg.bits_label();
        assert_eq!(label.len(), n);
        assert_eq!(label.chars().filter(|&c| c == '1').count(), cfg.n_quantized());
    }
}
