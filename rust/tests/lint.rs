//! Self-tests for the `ampq lint` static-analysis pass: every rule fires
//! on its seeded fixture, suppressions are audited rather than silent,
//! the baseline round-trips, and — the point of the exercise — the repo
//! itself is clean.

use ampq::analyze::{baseline_json, load_baseline, run, LintConfig, CATALOG};
use std::path::PathBuf;

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(name: &str) -> PathBuf {
    root().join("tests/lint_fixtures").join(name)
}

fn lint_one(name: &str) -> ampq::analyze::Report {
    run(&LintConfig { paths: vec![fixture(name)], baseline: None }).expect("lint fixture")
}

#[test]
fn each_rule_fires_on_its_fixture() {
    for (rule, file) in
        [("D1", "d1.rs"), ("D2", "d2.rs"), ("D3", "d3.rs"), ("D4", "d4.rs"), ("D5", "d5.rs")]
    {
        let report = lint_one(file);
        assert!(!report.clean(), "{file} should trip the linter");
        assert!(
            report.findings.iter().all(|f| f.rule == rule),
            "{file} should only produce {rule} findings, got {:?}",
            report.findings
        );
        assert_eq!(report.findings.len(), 1, "{file} seeds exactly one violation");
        assert!(report.findings[0].line > 0);
        assert!(!report.findings[0].excerpt.is_empty());
    }
}

#[test]
fn clean_fixture_is_clean() {
    let report = lint_one("clean.rs");
    assert!(report.clean(), "clean.rs must pass: {:?}", report.findings);
    assert!(report.suppressed.is_empty());
}

#[test]
fn rule_catalog_matches_fixture_set() {
    let ids: Vec<&str> = CATALOG.iter().map(|r| r.id).collect();
    assert_eq!(ids, ["D1", "D2", "D3", "D4", "D5"]);
}

#[test]
fn d2_sorted_suppression_is_audited_not_silent() {
    let report = lint_one("d2.rs");
    // The `emit_presorted` iteration is silenced by `// lint: sorted …`,
    // but the audit trail keeps it visible.
    assert_eq!(report.suppressed.len(), 1, "suppressed: {:?}", report.suppressed.len());
    assert_eq!(report.suppressed[0].finding.rule, "D2");
    assert!(
        report.suppressed[0].reason.contains("key order"),
        "directive reason survives: {:?}",
        report.suppressed[0].reason
    );
}

#[test]
fn d4_poison_witness_is_carved_out() {
    let report = lint_one("d4.rs");
    let d4: Vec<_> = report.findings.iter().filter(|f| f.rule == "D4").collect();
    // `parse().unwrap()` fires; `lock().expect(..)` does not (a poisoned
    // lock is itself a prior panic — the expect is a witness).
    assert_eq!(d4.len(), 1);
    assert!(d4[0].excerpt.contains("parse"), "wrong site: {:?}", d4[0].excerpt);
}

#[test]
fn baseline_round_trips_through_json() {
    let report = lint_one("d1.rs");
    let j = baseline_json(&report.findings.iter().collect::<Vec<_>>());
    let dir = std::env::temp_dir().join("ampq-lint-integration");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("roundtrip-baseline.json");
    std::fs::write(&path, j.to_string()).expect("write baseline");

    let entries = load_baseline(&path).expect("parse baseline");
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].rule, "D1");

    // With the baseline applied the same fixture is non-fatal.
    let report =
        run(&LintConfig { paths: vec![fixture("d1.rs")], baseline: Some(path) }).unwrap();
    assert!(report.clean());
    assert_eq!(report.baselined.len(), 1);
    assert!(report.stale_baseline.is_empty());
}

/// The acceptance gate: `ampq lint` over the whole crate (src + tests,
/// fixtures excluded by the walk) is clean against the committed baseline,
/// the baseline carries no stale debt, and — per the burn-down contract —
/// no D1 entries at all.
#[test]
fn repo_is_clean_under_committed_baseline() {
    let baseline = root().join("lint-baseline.json");
    let report = run(&LintConfig {
        paths: vec![root().join("src"), root().join("tests")],
        baseline: Some(baseline.clone()),
    })
    .expect("lint repo");
    assert!(
        report.clean(),
        "new lint findings:\n{}",
        report
            .findings
            .iter()
            .map(|f| format!("  {} {}:{} {}", f.rule, f.file, f.line, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        report.stale_baseline.is_empty(),
        "baseline entries already paid off: {:?}",
        report.stale_baseline
    );
    let entries = load_baseline(&baseline).expect("baseline parses");
    assert!(
        entries.iter().all(|e| e.rule != "D1"),
        "D1 debt may not be baselined (fix it with total_cmp)"
    );
    assert!(report.files_scanned > 40, "walk found {} files", report.files_scanned);
}
