//! The promoted fuzz suite.
//!
//! Two tiers:
//!
//! * [`fuzz_smoke`] — a ~2s seeded slice of the solver-oracle fuzz that
//!   runs in plain `cargo test`, so the differential harness itself can
//!   never rot behind `--ignored` (the full campaign stays in
//!   `tests/parametric.rs::fuzz_solver_oracle_small_instances`, run by
//!   the CI fuzz job);
//! * [`corpus_replays_minimized_failures`] — replays every minimized
//!   failure under `tests/corpus/*.json`.  Each file is one regression
//!   the fuzzer (or a release) once caught: add new findings here,
//!   minimized, instead of growing the smoke loop.
//!
//! Plus the structured-input generators: random-but-valid device
//! profiles and serve requests, differentially checked through their
//! JSON round trips (the wire the dist worker fleet and the serve
//! daemon both ride) and through `serve_batch_lossy`, which must answer
//! every fuzzed entry with an indexed line — never a panic.
//!
//! Corpus schema (one object per file):
//!
//! ```json
//! {"kind": "mckp_oracle", "gains": [[...]], "costs": [[...]], "budget": X}
//! {"kind": "tau_reject", "tau": "nan" | "inf" | -0.004}
//! ```
//!
//! `tau` may be a string so non-finite values survive JSON.

use ampq::backend::{DeviceProfile, RateTable};
use ampq::coordinator::Strategy;
use ampq::exec::{ExecCfg, ExecPool};
use ampq::metrics::Objective;
use ampq::numerics::Format;
use ampq::plan::demo::demo_model;
use ampq::plan::service::{error_entry, indexed};
use ampq::plan::{Engine, PlanRequest, PlanService, ServeRequest};
use ampq::solver::problem::gen::{random, random_multi};
use ampq::solver::{branch_bound, dp, greedy, parametric, Mckp};
use ampq::util::{Json, Rng};
use std::path::PathBuf;

/// Pointwise branch & bound at an explicit primary budget.
fn solve_at(p: &Mckp, primary_budget: f64) -> ampq::solver::Solution {
    let mut q = p.clone();
    q.budgets[0] = primary_budget;
    branch_bound::solve(&q)
}

/// The differential check every fuzzed or replayed instance must pass:
/// branch & bound matches brute force, greedy/dp never beat it, and the
/// parametric curve's knots agree with pointwise solves.
fn check_against_oracle(p: &Mckp, label: &str) {
    let exact = p.brute_force();
    let bb = branch_bound::solve(p);
    assert_eq!(bb.feasible, exact.feasible, "{label}");
    if exact.feasible {
        assert!(
            (bb.gain - exact.gain).abs() < 1e-9,
            "{label}: bb {} vs brute {}",
            bb.gain,
            exact.gain
        );
    }
    let g = greedy::solve(p);
    if g.feasible {
        assert!(p.fits(&g.costs), "{label}: greedy returned an infeasible pick");
        assert!(
            g.gain <= exact.gain + 1e-9,
            "{label}: greedy {} beats brute {}",
            g.gain,
            exact.gain
        );
    }
    if p.budgets.len() == 1 {
        let d = dp::solve(p);
        assert_eq!(d.feasible, exact.feasible, "{label}: dp feasibility");
        if d.feasible {
            assert!(d.cost <= p.budget() + 1e-9, "{label}: dp over budget");
            assert!(d.gain <= exact.gain + 1e-9, "{label}: dp beats brute");
        }
    }
    let mut curve = parametric::frontier(p);
    if !curve.exact {
        curve = parametric::harden_with(p, curve, &ExecPool::sequential());
    }
    if curve.is_empty() {
        assert!(!exact.feasible, "{label}: empty curve on a feasible instance");
        return;
    }
    // Knot gains never overstate the pointwise oracle (sub-EPS cost gaps
    // can let the oracle legitimately exceed a knot — see parametric.rs).
    for pt in &curve.points {
        let s = solve_at(p, pt.cost());
        assert!(
            s.feasible && s.gain >= pt.gain - 1e-9,
            "{label}: oracle {} below knot {}",
            s.gain,
            pt.gain
        );
    }
    if exact.feasible {
        let top = curve.points.last().unwrap();
        assert!(
            (top.gain - exact.gain).abs() < 1e-9,
            "{label}: top knot {} vs brute {}",
            top.gain,
            exact.gain
        );
    }
}

/// Always-on fuzz slice: small instances, fixed seeds, ~2s in a debug
/// build.  The full campaign (40 seeds x 60 trials, larger instances) is
/// `parametric.rs::fuzz_solver_oracle_small_instances` under `--ignored`.
#[test]
fn fuzz_smoke() {
    for seed in 0..4u64 {
        let mut rng = Rng::new(0x50_0E ^ (seed << 8));
        for trial in 0..20 {
            let p = if trial % 2 == 0 {
                random(&mut rng, 4, 4)
            } else {
                random_multi(&mut rng, 3, 3, 2)
            };
            check_against_oracle(&p, &format!("seed {seed} trial {trial}"));
        }
    }
}

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("corpus")
}

fn f64_field(j: &Json, key: &str, file: &str) -> f64 {
    match j.get(key).unwrap_or_else(|e| panic!("{file}: {e:#}")) {
        Json::Num(x) => *x,
        // Strings carry non-finite values (JSON numbers cannot).
        Json::Str(s) => s
            .parse::<f64>()
            .unwrap_or_else(|e| panic!("{file}: bad {key} '{s}': {e}")),
        other => panic!("{file}: {key} must be a number or string, got {other:?}"),
    }
}

fn table(j: &Json, key: &str, file: &str) -> Vec<Vec<f64>> {
    let rows = j
        .get(key)
        .and_then(|v| v.arr())
        .unwrap_or_else(|e| panic!("{file}: bad {key}: {e:#}"));
    rows.iter()
        .map(|row| {
            row.arr()
                .unwrap_or_else(|e| panic!("{file}: bad {key} row: {e:#}"))
                .iter()
                .map(|x| {
                    x.f64().unwrap_or_else(|e| panic!("{file}: bad {key} value: {e:#}"))
                })
                .collect()
        })
        .collect()
}

fn replay_tau_reject(tau: f64, file: &str) {
    let (graph, qlayers, calibration) = demo_model(1, 3);
    let mut engine = Engine::new();
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let svc = PlanService::from_engine(&mut engine, &["demo"]).unwrap();
    let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau);
    assert!(svc.solve("demo", &req).is_err(), "{file}: tau {tau} must be rejected");
    let lookup = ServeRequest::new("demo", req).via_frontier();
    assert!(svc.answer(&lookup).is_err(), "{file}: tau {tau} lookup must error");
    // The lossy batch completes with an indexed error, never a panic.
    let good = ServeRequest::new(
        "demo",
        PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004),
    );
    let out = svc.serve_batch_lossy(
        &[good.clone(), lookup, good],
        &ExecPool::new(ExecCfg::new(2)),
    );
    assert_eq!(out.len(), 3, "{file}");
    assert_eq!(
        out[1].get("kind").and_then(|k| k.str().map(str::to_string)).unwrap(),
        "error",
        "{file}: entry 1 must be an indexed error"
    );
}

/// Replay every minimized failure in `tests/corpus/`.  Seeded with the
/// NaN/inf/negative-tau rejects and the degenerate-hull instances that
/// destabilized the pre-hardening frontier solver.
#[test]
fn corpus_replays_minimized_failures() {
    let dir = corpus_dir();
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()))
        .filter_map(|entry| {
            let path = entry.unwrap().path();
            (path.extension().and_then(|x| x.to_str()) == Some("json")).then_some(path)
        })
        .collect();
    files.sort();
    assert!(
        files.len() >= 6,
        "corpus unexpectedly small ({} files) — was it checked in?",
        files.len()
    );
    for path in files {
        let file = path.file_name().unwrap().to_string_lossy().to_string();
        let j = Json::parse_file(&path).unwrap_or_else(|e| panic!("{file}: {e:#}"));
        let kind = j
            .get("kind")
            .and_then(|k| k.str().map(str::to_string))
            .unwrap_or_else(|e| panic!("{file}: {e:#}"));
        match kind.as_str() {
            "mckp_oracle" => {
                let gains = table(&j, "gains", &file);
                let costs = table(&j, "costs", &file);
                let budget = f64_field(&j, "budget", &file);
                let p = Mckp::new(gains, costs, budget)
                    .unwrap_or_else(|e| panic!("{file}: {e:#}"));
                check_against_oracle(&p, &file);
            }
            "tau_reject" => replay_tau_reject(f64_field(&j, "tau", &file), &file),
            other => panic!("{file}: unknown corpus kind '{other}'"),
        }
    }
}

// ---------------------------------------------------------------------------
// Structured generators: device profiles and serve requests.
// ---------------------------------------------------------------------------

/// A random device profile that [`DeviceProfile::validate`] must accept:
/// every field is drawn from its legal range (positive finite rooflines
/// and rates, >=1 engines, BF16 always supported).
fn random_device_profile(rng: &mut Rng, tag: u64) -> DeviceProfile {
    let mut p = DeviceProfile::gaudi2();
    p.name = format!("fuzz-dev-{tag}");
    p.n_mme = rng.range(1, 17);
    p.n_tpc = rng.range(1, 65);
    p.mme_macs_per_us = 1.0 + rng.f64() * 1.0e7;
    p.tpc_bytes_per_us = 1.0 + rng.f64() * 1.0e6;
    p.hbm_bytes_per_us = 1.0 + rng.f64() * 1.0e6;
    p.launch_us = rng.f64() * 10.0;
    p.noise_std = rng.f64() * 0.05;
    p.enable_fusion = rng.bool();
    p.hbm_capacity_bytes = (rng.f64() * 1.0e11).floor();
    let mut rates = RateTable::uniform(0.25 + rng.f64() * 4.0);
    for f in Format::ALL {
        if rng.bool() {
            rates.set(f, 0.1 + rng.f64() * 8.0);
        }
    }
    p.mme_rates = rates;
    let mut supported = vec![Format::Bf16];
    for f in Format::ALL {
        if f != Format::Bf16 && rng.bool() {
            supported.push(f);
        }
    }
    p.supported = supported;
    p
}

/// Every generated profile validates, survives a JSON text round trip
/// bit-identically (re-encoding is byte-stable — artifact trees are
/// compared with `diff -r` across worker counts), keeps its filesystem
/// key, and restricts menus to exactly its supported mask in menu order.
#[test]
fn fuzz_device_profile_roundtrip_and_menus() {
    for seed in 0..4u64 {
        let mut rng = Rng::stream(0xDE_71CE, seed);
        for trial in 0..16u64 {
            let p = random_device_profile(&mut rng, seed * 100 + trial);
            let label = format!("profile seed {seed} trial {trial}");
            p.validate().unwrap_or_else(|e| panic!("{label}: {e:#}"));
            let text = p.to_json().to_string();
            let back = DeviceProfile::from_json(&Json::parse(&text).unwrap())
                .unwrap_or_else(|e| panic!("{label}: {e:#}"));
            assert_eq!(back, p, "{label}: JSON round trip drifted");
            assert_eq!(back.fs_key(), p.fs_key(), "{label}: fs_key drifted");
            assert_eq!(back.to_json().to_string(), text, "{label}: re-encode unstable");
            let menu = p.restrict_menu(&Format::ALL);
            assert!(menu.contains(&Format::Bf16), "{label}: baseline dropped");
            let expect: Vec<Format> =
                Format::ALL.iter().copied().filter(|f| p.supports(*f)).collect();
            assert_eq!(menu, expect, "{label}: restrict_menu must keep menu order");
        }
    }
}

/// Rebuild a JSON object with one top-level key replaced.
fn with_key(j: &Json, key: &str, val: Json) -> Json {
    match j {
        Json::Obj(kv) => Json::Obj(
            kv.iter()
                .map(|(k, v)| {
                    (k.clone(), if k == key { val.clone() } else { v.clone() })
                })
                .collect(),
        ),
        other => other.clone(),
    }
}

/// Each single-field corruption of a valid profile must be rejected by
/// `from_json` — including after a text round trip, which is the path
/// user profile files actually take (`--device profile.json`).
#[test]
fn doctored_device_profiles_are_rejected() {
    let base = DeviceProfile::gaudi2().to_json();
    assert!(DeviceProfile::from_json(&base).is_ok(), "baseline must load");
    let rates_zero = match base.get("mme_rates").unwrap() {
        Json::Obj(kv) => {
            Json::Obj(kv.iter().map(|(k, _)| (k.clone(), Json::Num(0.0))).collect())
        }
        other => panic!("mme_rates must be an object, got {other:?}"),
    };
    let cases = vec![
        ("empty name", with_key(&base, "name", Json::Str(String::new()))),
        ("zero mme engines", with_key(&base, "n_mme", Json::Num(0.0))),
        ("zero tpc engines", with_key(&base, "n_tpc", Json::Num(0.0))),
        ("negative roofline", with_key(&base, "hbm_bytes_per_us", Json::Num(-1.0))),
        ("zero roofline", with_key(&base, "mme_macs_per_us", Json::Num(0.0))),
        ("negative launch", with_key(&base, "launch_us", Json::Num(-0.5))),
        ("negative capacity", with_key(&base, "hbm_capacity_bytes", Json::Num(-1.0))),
        ("zero mme rates", with_key(&base, "mme_rates", rates_zero)),
        (
            "baseline format unsupported",
            with_key(
                &base,
                "supported_formats",
                Json::Arr(vec![Json::Str("fp8_e4m3".to_string())]),
            ),
        ),
        (
            "unknown format name",
            with_key(
                &base,
                "supported_formats",
                Json::Arr(vec![Json::Str("bf16".to_string()), Json::Str("int8".to_string())]),
            ),
        ),
        (
            "non-bool fusion flag",
            with_key(&base, "enable_fusion", Json::Str("yes".to_string())),
        ),
    ];
    for (what, doctored) in cases {
        let reparsed = Json::parse(&doctored.to_string()).unwrap();
        assert!(
            DeviceProfile::from_json(&reparsed).is_err(),
            "doctored profile ({what}) was accepted"
        );
    }
}

/// A random plan request whose JSON form is valid: budgets stay finite
/// and non-negative here (non-finite values cannot ride JSON numbers —
/// they are fuzzed as struct fields in the lossy-batch test below).
fn random_plan_request(rng: &mut Rng) -> PlanRequest {
    let mut r = PlanRequest::new(Objective::ALL[rng.below(Objective::ALL.len())]);
    r = r.with_strategy(Strategy::ALL[rng.below(Strategy::ALL.len())]);
    if rng.bool() {
        r = r.with_loss_budget(1.0e-6 + rng.f64() * 0.01);
    }
    if rng.bool() {
        r = r.with_memory_cap(1.0 + rng.f64() * 1.0e9);
    }
    if rng.bool() {
        r = r.with_seed(rng.next_u64());
    }
    if rng.bool() {
        r = r.with_device(["gaudi2", "gaudi3"][rng.below(2)]);
    }
    r
}

/// Serve requests round-trip through their JSON text exactly — fields,
/// u64 seeds (string-carried), and float budgets bit-for-bit — and
/// re-encode to the identical byte string.
#[test]
fn fuzz_serve_request_json_roundtrip_is_stable() {
    let mut rng = Rng::new(0x5EB7_FA77);
    for trial in 0..64 {
        let mut sr =
            ServeRequest::new(["demo", "other-model"][rng.below(2)], random_plan_request(&mut rng));
        if rng.bool() {
            sr = sr.via_frontier();
        }
        let text = sr.to_json().to_string();
        let back = ServeRequest::from_json(&Json::parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("trial {trial}: {e:#} ({text})"));
        assert_eq!(back, sr, "trial {trial}: round trip drifted");
        assert_eq!(back.to_json().to_string(), text, "trial {trial}: re-encode unstable");
    }
}

/// Hostile `x-ampq-trace` headers against a live daemon, over a raw
/// socket so malformed bytes reach the parser unfiltered.  Every hostile
/// value must answer 400 — never a panic, never a solve — the trace ids
/// must never enter the span registry, the trace context must not leak
/// across keep-alive requests, and the daemon must keep serving.
#[test]
fn hostile_trace_headers_answer_400_without_panicking_or_leaking_spans() {
    use ampq::serve::client::{request as one_shot, Client};
    use ampq::serve::{Daemon, ServeConfig};
    use std::io::{Read, Write};

    let (graph, qlayers, calibration) = demo_model(1, 3);
    let mut engine = Engine::new();
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let svc = PlanService::from_engine(&mut engine, &["demo"]).unwrap();
    let cfg = ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() };
    let daemon =
        std::sync::Arc::new(Daemon::new(svc, vec![DeviceProfile::gaudi2()], cfg));
    let listener = daemon.bind().unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let d = daemon.clone();
    let join = std::thread::spawn(move || d.run(listener).unwrap());

    // One raw exchange: write the request bytes, half-close, read until
    // the daemon closes (it sees EOF after answering).
    let raw = |payload: &[u8]| -> String {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
        s.write_all(payload).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = Vec::new();
        let _ = s.read_to_end(&mut out);
        String::from_utf8_lossy(&out).into_owned()
    };
    let with_header = |value: &[u8]| -> Vec<u8> {
        let mut req = Vec::new();
        req.extend_from_slice(
            b"POST /v1/plan HTTP/1.1\r\nHost: ampq\r\nContent-Length: 2\r\nx-ampq-trace: ",
        );
        req.extend_from_slice(value);
        req.extend_from_slice(b"\r\n\r\n{}");
        req
    };

    let oversized = "a".repeat(65);
    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("oversized id (65 chars)", with_header(oversized.as_bytes())),
        ("empty id", with_header(b"")),
        ("embedded spaces", with_header(b"not a valid id")),
        ("response-splitting chars", with_header(b"abc%0d%0aset-cookie:x")),
        ("quoted id", with_header(b"\"quoted\"")),
        ("non-utf8 bytes", with_header(&[0xff, 0xfe, 0x80])),
    ];
    for (what, req) in cases {
        let resp = raw(&req);
        assert!(
            resp.starts_with("HTTP/1.1 400"),
            "{what}: expected 400, got: {}",
            resp.lines().next().unwrap_or("<no response>")
        );
    }
    // None of the rejected ids may have entered the span registry.
    assert!(ampq::obs::spans_for(&oversized).is_empty(), "oversized id leaked spans");
    assert!(ampq::obs::spans_for("not a valid id").is_empty(), "invalid id leaked spans");

    // The trace context is per-request, not per-connection: a follow-up
    // request without a header gets a FRESH id, not the previous one.
    let body = ServeRequest::new(
        "demo",
        PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004),
    )
    .to_json()
    .to_string();
    let mut c = Client::connect(&addr).unwrap();
    let r1 = c
        .request_with_headers(
            "POST",
            "/v1/plan",
            Some(body.as_str()),
            &[("x-ampq-trace", "fuzz-keepalive-1")],
        )
        .unwrap();
    assert_eq!(r1.status, 200);
    assert_eq!(r1.header("x-ampq-trace"), Some("fuzz-keepalive-1"));
    let r2 = c.request("POST", "/v1/plan", Some(body.as_str())).unwrap();
    assert_eq!(r2.status, 200);
    let fresh = r2.header("x-ampq-trace").expect("fresh trace id missing");
    assert_ne!(fresh, "fuzz-keepalive-1", "trace context leaked across requests");

    // Still alive and healthy after the abuse.
    assert_eq!(one_shot(&addr, "GET", "/healthz", None).unwrap().status, 200);
    daemon.handle().shutdown();
    join.join().unwrap();
}

/// Fuzzed serve batches — unknown models, non-finite budgets, frontier
/// lookups with the wrong strategy — always complete with one indexed
/// line per entry, and every line equals the sequential `answer` path's
/// verdict (indexed answer or indexed error).  Never a panic.
#[test]
fn fuzz_lossy_batches_never_panic_and_match_sequential_answers() {
    let (graph, qlayers, calibration) = demo_model(1, 3);
    let mut engine = Engine::new();
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let svc = PlanService::from_engine(&mut engine, &["demo"]).unwrap();
    let pool = ExecPool::new(ExecCfg::new(2));
    let mut rng = Rng::new(0xBA7C_4);
    for round in 0..6 {
        let reqs: Vec<ServeRequest> = (0..12)
            .map(|_| {
                let mut r = random_plan_request(&mut rng);
                match rng.below(8) {
                    0 => r.tau = Some(f64::NAN),
                    1 => r.tau = Some(f64::INFINITY),
                    2 => r.memory_cap = Some(f64::NEG_INFINITY),
                    _ => {}
                }
                let model = if rng.below(4) == 0 { "ghost" } else { "demo" };
                let mut sr = ServeRequest::new(model, r);
                if rng.bool() {
                    sr = sr.via_frontier();
                }
                sr
            })
            .collect();
        let out = svc.serve_batch_lossy(&reqs, &pool);
        assert_eq!(out.len(), reqs.len(), "round {round}: entry dropped");
        for (i, (line, req)) in out.iter().zip(&reqs).enumerate() {
            match svc.answer(req) {
                Ok(answer) => assert_eq!(
                    line,
                    &indexed(i, answer),
                    "round {round} entry {i}: lossy line diverged from answer()"
                ),
                Err(e) => assert_eq!(
                    line,
                    &error_entry(i, &format!("{e:#}")),
                    "round {round} entry {i}: error line diverged"
                ),
            }
        }
    }
}
