//! The promoted fuzz suite.
//!
//! Two tiers:
//!
//! * [`fuzz_smoke`] — a ~2s seeded slice of the solver-oracle fuzz that
//!   runs in plain `cargo test`, so the differential harness itself can
//!   never rot behind `--ignored` (the full campaign stays in
//!   `tests/parametric.rs::fuzz_solver_oracle_small_instances`, run by
//!   the CI fuzz job);
//! * [`corpus_replays_minimized_failures`] — replays every minimized
//!   failure under `tests/corpus/*.json`.  Each file is one regression
//!   the fuzzer (or a release) once caught: add new findings here,
//!   minimized, instead of growing the smoke loop.
//!
//! Corpus schema (one object per file):
//!
//! ```json
//! {"kind": "mckp_oracle", "gains": [[...]], "costs": [[...]], "budget": X}
//! {"kind": "tau_reject", "tau": "nan" | "inf" | -0.004}
//! ```
//!
//! `tau` may be a string so non-finite values survive JSON.

use ampq::exec::{ExecCfg, ExecPool};
use ampq::metrics::Objective;
use ampq::plan::demo::demo_model;
use ampq::plan::{Engine, PlanRequest, PlanService, ServeRequest};
use ampq::solver::problem::gen::{random, random_multi};
use ampq::solver::{branch_bound, dp, greedy, parametric, Mckp};
use ampq::util::{Json, Rng};
use std::path::PathBuf;

/// Pointwise branch & bound at an explicit primary budget.
fn solve_at(p: &Mckp, primary_budget: f64) -> ampq::solver::Solution {
    let mut q = p.clone();
    q.budgets[0] = primary_budget;
    branch_bound::solve(&q)
}

/// The differential check every fuzzed or replayed instance must pass:
/// branch & bound matches brute force, greedy/dp never beat it, and the
/// parametric curve's knots agree with pointwise solves.
fn check_against_oracle(p: &Mckp, label: &str) {
    let exact = p.brute_force();
    let bb = branch_bound::solve(p);
    assert_eq!(bb.feasible, exact.feasible, "{label}");
    if exact.feasible {
        assert!(
            (bb.gain - exact.gain).abs() < 1e-9,
            "{label}: bb {} vs brute {}",
            bb.gain,
            exact.gain
        );
    }
    let g = greedy::solve(p);
    if g.feasible {
        assert!(p.fits(&g.costs), "{label}: greedy returned an infeasible pick");
        assert!(
            g.gain <= exact.gain + 1e-9,
            "{label}: greedy {} beats brute {}",
            g.gain,
            exact.gain
        );
    }
    if p.budgets.len() == 1 {
        let d = dp::solve(p);
        assert_eq!(d.feasible, exact.feasible, "{label}: dp feasibility");
        if d.feasible {
            assert!(d.cost <= p.budget() + 1e-9, "{label}: dp over budget");
            assert!(d.gain <= exact.gain + 1e-9, "{label}: dp beats brute");
        }
    }
    let mut curve = parametric::frontier(p);
    if !curve.exact {
        curve = parametric::harden_with(p, curve, &ExecPool::sequential());
    }
    if curve.is_empty() {
        assert!(!exact.feasible, "{label}: empty curve on a feasible instance");
        return;
    }
    // Knot gains never overstate the pointwise oracle (sub-EPS cost gaps
    // can let the oracle legitimately exceed a knot — see parametric.rs).
    for pt in &curve.points {
        let s = solve_at(p, pt.cost());
        assert!(
            s.feasible && s.gain >= pt.gain - 1e-9,
            "{label}: oracle {} below knot {}",
            s.gain,
            pt.gain
        );
    }
    if exact.feasible {
        let top = curve.points.last().unwrap();
        assert!(
            (top.gain - exact.gain).abs() < 1e-9,
            "{label}: top knot {} vs brute {}",
            top.gain,
            exact.gain
        );
    }
}

/// Always-on fuzz slice: small instances, fixed seeds, ~2s in a debug
/// build.  The full campaign (40 seeds x 60 trials, larger instances) is
/// `parametric.rs::fuzz_solver_oracle_small_instances` under `--ignored`.
#[test]
fn fuzz_smoke() {
    for seed in 0..4u64 {
        let mut rng = Rng::new(0x50_0E ^ (seed << 8));
        for trial in 0..20 {
            let p = if trial % 2 == 0 {
                random(&mut rng, 4, 4)
            } else {
                random_multi(&mut rng, 3, 3, 2)
            };
            check_against_oracle(&p, &format!("seed {seed} trial {trial}"));
        }
    }
}

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("corpus")
}

fn f64_field(j: &Json, key: &str, file: &str) -> f64 {
    match j.get(key).unwrap_or_else(|e| panic!("{file}: {e:#}")) {
        Json::Num(x) => *x,
        // Strings carry non-finite values (JSON numbers cannot).
        Json::Str(s) => s
            .parse::<f64>()
            .unwrap_or_else(|e| panic!("{file}: bad {key} '{s}': {e}")),
        other => panic!("{file}: {key} must be a number or string, got {other:?}"),
    }
}

fn table(j: &Json, key: &str, file: &str) -> Vec<Vec<f64>> {
    let rows = j
        .get(key)
        .and_then(|v| v.arr())
        .unwrap_or_else(|e| panic!("{file}: bad {key}: {e:#}"));
    rows.iter()
        .map(|row| {
            row.arr()
                .unwrap_or_else(|e| panic!("{file}: bad {key} row: {e:#}"))
                .iter()
                .map(|x| {
                    x.f64().unwrap_or_else(|e| panic!("{file}: bad {key} value: {e:#}"))
                })
                .collect()
        })
        .collect()
}

fn replay_tau_reject(tau: f64, file: &str) {
    let (graph, qlayers, calibration) = demo_model(1, 3);
    let mut engine = Engine::new();
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let svc = PlanService::from_engine(&mut engine, &["demo"]).unwrap();
    let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau);
    assert!(svc.solve("demo", &req).is_err(), "{file}: tau {tau} must be rejected");
    let lookup = ServeRequest::new("demo", req).via_frontier();
    assert!(svc.answer(&lookup).is_err(), "{file}: tau {tau} lookup must error");
    // The lossy batch completes with an indexed error, never a panic.
    let good = ServeRequest::new(
        "demo",
        PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004),
    );
    let out = svc.serve_batch_lossy(
        &[good.clone(), lookup, good],
        &ExecPool::new(ExecCfg::new(2)),
    );
    assert_eq!(out.len(), 3, "{file}");
    assert_eq!(
        out[1].get("kind").and_then(|k| k.str().map(str::to_string)).unwrap(),
        "error",
        "{file}: entry 1 must be an indexed error"
    );
}

/// Replay every minimized failure in `tests/corpus/`.  Seeded with the
/// NaN/inf/negative-tau rejects and the degenerate-hull instances that
/// destabilized the pre-hardening frontier solver.
#[test]
fn corpus_replays_minimized_failures() {
    let dir = corpus_dir();
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()))
        .filter_map(|entry| {
            let path = entry.unwrap().path();
            (path.extension().and_then(|x| x.to_str()) == Some("json")).then_some(path)
        })
        .collect();
    files.sort();
    assert!(
        files.len() >= 6,
        "corpus unexpectedly small ({} files) — was it checked in?",
        files.len()
    );
    for path in files {
        let file = path.file_name().unwrap().to_string_lossy().to_string();
        let j = Json::parse_file(&path).unwrap_or_else(|e| panic!("{file}: {e:#}"));
        let kind = j
            .get("kind")
            .and_then(|k| k.str().map(str::to_string))
            .unwrap_or_else(|e| panic!("{file}: {e:#}"));
        match kind.as_str() {
            "mckp_oracle" => {
                let gains = table(&j, "gains", &file);
                let costs = table(&j, "costs", &file);
                let budget = f64_field(&j, "budget", &file);
                let p = Mckp::new(gains, costs, budget)
                    .unwrap_or_else(|e| panic!("{file}: {e:#}"));
                check_against_oracle(&p, &file);
            }
            "tau_reject" => replay_tau_reject(f64_field(&j, "tau", &file), &file),
            other => panic!("{file}: unknown corpus kind '{other}'"),
        }
    }
}
