//! End-to-end tests for the distributed planning layer (`src/dist/`).
//!
//! The acceptance contract under test:
//!
//! * `ampq fleet` artifact trees are byte-identical at ANY worker count —
//!   including 0 (the in-process reference path) — over a models × devices
//!   matrix, and including runs where a worker is killed mid-run;
//! * supervision accounting (crashes, deadline expiries, retries,
//!   respawns) is observable and bounded;
//! * the TCP transport produces the same bytes as stdio pipes;
//! * the coordinator's high-level ops (calibrate, measure, frontier)
//!   match their in-process counterparts exactly, including when routed
//!   through `Engine::set_measure_hook`.
//!
//! Workers are real `ampq worker` subprocesses (`CARGO_BIN_EXE_ampq`).

use ampq::backend::DeviceProfile;
use ampq::dist::{
    run_fleet, Coordinator, DistConfig, FleetConfig, TaskSpec, Transport,
};
use ampq::exec::ExecPool;
use ampq::metrics::Objective;
use ampq::numerics::PAPER_FORMATS;
use ampq::plan::demo::{demo_calibration, demo_model};
use ampq::plan::engine::{DEFAULT_MEASURE_REPS, DEFAULT_MEASURE_SEED};
use ampq::plan::stage::{MeasureStage, PartitionStage, Stage};
use ampq::plan::{Engine, PlanRequest};
use ampq::solver::parametric;
use ampq::solver::problem::gen::random_multi;
use ampq::util::{Json, Rng};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A DistConfig pointing at the real worker binary Cargo built for this
/// test run (the coordinator cannot infer it from the test executable).
fn dist_cfg(workers: usize) -> DistConfig {
    DistConfig {
        workers,
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_ampq"))),
        retry_backoff: Duration::from_millis(10),
        ..DistConfig::default()
    }
}

/// Every file under `root`, keyed by relative path, as text (all fleet
/// artifacts are JSON).
fn read_tree(root: &Path) -> BTreeMap<String, String> {
    fn walk(dir: &Path, root: &Path, out: &mut BTreeMap<String, String>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                walk(&path, root, out);
            } else {
                let rel =
                    path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                out.insert(rel, std::fs::read_to_string(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

fn assert_trees_equal(
    a: &BTreeMap<String, String>,
    b: &BTreeMap<String, String>,
    what: &str,
) {
    assert_eq!(
        a.keys().collect::<Vec<_>>(),
        b.keys().collect::<Vec<_>>(),
        "{what}: file sets differ"
    );
    for (path, text) in a {
        assert_eq!(text, &b[path], "{what}: {path} differs");
    }
}

/// Run one fleet over a unique temp dir and return (artifact tree,
/// supervision metrics).
fn fleet_tree(
    tag: &str,
    models: &[&str],
    devices: &[&str],
    workers: usize,
    dist: DistConfig,
) -> (BTreeMap<String, String>, ampq::dist::DistMetrics) {
    let out =
        std::env::temp_dir().join(format!("ampq_dist_{}_{tag}", std::process::id()));
    std::fs::remove_dir_all(&out).ok();
    let cfg = FleetConfig {
        models: models.iter().map(|s| s.to_string()).collect(),
        devices: devices.iter().map(|s| s.to_string()).collect(),
        workers,
        out: out.clone(),
        blocks: 1,
        dist,
    };
    let report = run_fleet(&cfg).unwrap_or_else(|e| panic!("{tag}: {e:#}"));
    assert_eq!(report.cells.len(), models.len() * devices.len(), "{tag}");
    let tree = read_tree(&out);
    std::fs::remove_dir_all(&out).ok();
    (tree, report.metrics)
}

/// The headline determinism check: the full 2-model × 2-device matrix is
/// byte-identical in-process, with 1 worker, and with 4 workers.
#[test]
fn fleet_artifacts_are_byte_identical_across_worker_counts() {
    let models = ["demo", "tiny"];
    let devices = ["gaudi2", "gaudi3"];
    let (reference, m0) = fleet_tree("ref", &models, &devices, 0, dist_cfg(0));
    assert_eq!(m0, ampq::dist::DistMetrics::default(), "in-process runs no fleet");
    assert!(
        reference.keys().any(|k| k.starts_with("tiny/frontier-")),
        "reference tree incomplete: {:?}",
        reference.keys().collect::<Vec<_>>()
    );

    let (one, m1) = fleet_tree("w1", &models, &devices, 1, dist_cfg(1));
    assert_trees_equal(&reference, &one, "workers=1 vs in-process");

    let (four, m4) = fleet_tree("w4", &models, &devices, 4, dist_cfg(4));
    assert_trees_equal(&reference, &four, "workers=4 vs in-process");

    for (label, m) in [("workers=1", &m1), ("workers=4", &m4)] {
        assert!(m.tasks > 0, "{label}: no tasks ran on the fleet");
        assert_eq!(m.retries, 0, "{label}: unexpected retries on a healthy fleet");
        assert_eq!(m.worker_crashes, 0, "{label}: unexpected crashes");
        assert_eq!(m.deadline_expiries, 0, "{label}: unexpected expiries");
    }
    // Same task decomposition at both worker counts: the schedule changes,
    // the work does not.
    assert_eq!(m1.tasks, m4.tasks, "task count must not depend on fleet size");
}

/// Killing a worker mid-run (SIGKILL after 2 completed tasks) must leave
/// the artifact tree untouched — the crash is absorbed by re-issue — and
/// must be visible in the supervision counters.
#[test]
fn fleet_survives_a_worker_killed_mid_run_byte_identically() {
    let models = ["demo"];
    let devices = ["gaudi2", "gaudi3"];
    let (reference, _) = fleet_tree("kill_ref", &models, &devices, 0, dist_cfg(0));
    let hostile = DistConfig { debug_kill_after: Some(2), ..dist_cfg(2) };
    let (tree, m) = fleet_tree("kill", &models, &devices, 2, hostile);
    assert_trees_equal(&reference, &tree, "killed-worker run vs in-process");
    assert!(m.worker_crashes >= 1, "the kill went unnoticed: {m:?}");
    assert!(m.respawns >= 1, "the dead slot was never respawned: {m:?}");
}

/// Loopback TCP workers produce the same bytes as stdio-pipe workers.
#[test]
fn tcp_transport_matches_the_in_process_reference() {
    let models = ["demo"];
    let devices = ["gaudi2"];
    let (reference, _) = fleet_tree("tcp_ref", &models, &devices, 0, dist_cfg(0));
    let tcp = DistConfig { transport: Transport::Tcp, ..dist_cfg(2) };
    let (tree, m) = fleet_tree("tcp", &models, &devices, 2, tcp);
    assert_trees_equal(&reference, &tree, "tcp vs in-process");
    assert_eq!(m.worker_crashes, 0);
    assert!(m.tasks > 0);
}

/// A task that hangs past its deadline is killed and re-issued until the
/// retry budget runs out; the failure is surfaced, accounted, and leaves
/// the fleet usable for the next batch.
#[test]
fn deadline_expiries_are_bounded_and_accounted() {
    let cfg = DistConfig {
        task_deadline: Duration::from_millis(250),
        max_retries: 2,
        ..dist_cfg(1)
    };
    let mut c = Coordinator::new(cfg).unwrap();
    let hang = TaskSpec {
        kind: "sleep".to_string(),
        fields: vec![("ms".to_string(), Json::Num(60_000.0))],
        ctx: None,
    };
    let err = c.run_tasks(std::slice::from_ref(&hang));
    assert!(err.is_err(), "a permanently hanging task must fail the batch");
    let m = c.metrics().clone();
    // Initial attempt + 2 re-issues, each ending in a deadline kill; the
    // third kill exhausts the budget.
    assert_eq!(m.deadline_expiries, 3, "{m:?}");
    assert_eq!(m.retries, 3, "{m:?}");
    assert_eq!(m.tasks, 0, "{m:?}");
    // The fleet recovers: the dead slot respawns for the next batch.
    c.ping().unwrap();
    assert!(c.metrics().respawns >= 1);
    assert_eq!(c.metrics().tasks, 1);
    c.shutdown();
}

/// A task whose worker dies instead of answering exercises the crash
/// path: EOF detection, re-issue, bounded failure — without poisoning a
/// later healthy batch.
#[test]
fn worker_crashes_are_retried_then_surfaced() {
    let cfg = DistConfig { max_retries: 2, ..dist_cfg(1) };
    let mut c = Coordinator::new(cfg).unwrap();
    let die = TaskSpec {
        kind: "exit".to_string(),
        fields: vec![("code".to_string(), Json::Num(9.0))],
        ctx: None,
    };
    assert!(c.run_tasks(std::slice::from_ref(&die)).is_err());
    let m = c.metrics().clone();
    assert!(m.worker_crashes >= 3, "every attempt must register a crash: {m:?}");
    assert_eq!(m.retries, 3, "{m:?}");
    assert_eq!(m.tasks, 0, "{m:?}");
    c.ping().unwrap();
    c.shutdown();
}

/// The coordinator's high-level operations reproduce their in-process
/// counterparts exactly: calibration, the Measured stage, and the
/// parametric frontier sweep.
#[test]
fn coordinator_ops_match_in_process_bitwise() {
    let mut c = Coordinator::new(dist_cfg(2)).unwrap();
    c.ping().unwrap();

    // Calibration: a worker recomputes the pure demo calibration.
    let (graph, qlayers, _) = demo_model(1, 0xD157);
    let got = c.calibrate_demo(qlayers.len(), 0xD157).unwrap();
    assert_eq!(got, demo_calibration(qlayers.len(), 0xD157));

    // Measurement: the sharded fleet path vs the sequential stage.
    let device = DeviceProfile::gaudi2();
    let menu = device.restrict_menu(&PAPER_FORMATS);
    let seq = ExecPool::sequential();
    let partitioned =
        PartitionStage { model: "demo", graph: &graph, qlayers: &qlayers, menu: &menu }
            .run(&seq)
            .unwrap();
    let ms = MeasureStage {
        model: "demo",
        graph: &graph,
        partitioned: &partitioned,
        device: &device,
        seed: DEFAULT_MEASURE_SEED,
        reps: DEFAULT_MEASURE_REPS,
    };
    let want = ms.run(&seq).unwrap();
    let got = c.measure_stage(&ms).unwrap();
    assert_eq!(got, want, "distributed Measured artifact drifted");

    // Frontier: remote chunk expansion vs the in-process sweep, on a few
    // multi-dimensional instances.
    let mut rng = Rng::new(0xF20_17);
    for trial in 0..3 {
        let p = random_multi(&mut rng, 4, 3, 2);
        let want = parametric::frontier_with(&p, &seq);
        let got = c.frontier_curve(&p).unwrap();
        assert_eq!(got, want, "trial {trial}: distributed curve drifted");
    }
    c.shutdown();
}

/// `Engine::set_measure_hook` routes the measure stage through the fleet
/// without changing a single planning answer.
#[test]
fn engine_measure_hook_through_the_fleet_matches_default_path() {
    let (graph, qlayers, calibration) = demo_model(1, 11);

    let mut plain = Engine::new();
    plain.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
    let want = plain.planner("demo").unwrap();

    let coord = Arc::new(Mutex::new(Coordinator::new(dist_cfg(2)).unwrap()));
    let mut hooked = Engine::new();
    hooked.register_synthetic("demo", graph, qlayers, calibration);
    let h = coord.clone();
    hooked.set_measure_hook(Some(Box::new(move |ms| {
        h.lock().unwrap().measure_stage(ms)
    })));
    let got = hooked.planner("demo").unwrap();

    let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004);
    assert_eq!(got.solve(&req).unwrap(), want.solve(&req).unwrap());
    assert!(
        coord.lock().unwrap().metrics().tasks > 0,
        "the hook never reached the fleet"
    );
    coord.lock().unwrap().shutdown();
}
