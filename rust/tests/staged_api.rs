//! Acceptance suite for the staged planning API (no AOT artifacts or PJRT
//! needed — runs on the synthetic demo model):
//!
//! * a full tau x objective x strategy sweep costs EXACTLY one calibration
//!   pass and one time-measurement pass (Engine counters);
//! * a Plan serialized to JSON deserializes back equal (round-trip);
//! * stage artifacts persist to the on-disk cache and a fresh Engine solves
//!   the same grid with zero recomputation and identical plans.

use ampq::coordinator::{paper_tau_grid, Strategy};
use ampq::metrics::Objective;
use ampq::plan::demo::demo_model;
use ampq::plan::{Engine, Plan, PlanRequest};
use ampq::util::Json;
use std::path::PathBuf;

/// The scalar query shape the PR-1 acceptance tests were written against,
/// expressed on the 0.3+ request surface (the deprecated shim is gone).
fn solve(
    planner: &ampq::plan::Planner,
    objective: Objective,
    strategy: Strategy,
    tau: f64,
    seed: u64,
) -> Plan {
    planner
        .solve(
            &PlanRequest::new(objective)
                .with_strategy(strategy)
                .with_loss_budget(tau)
                .with_seed(seed),
        )
        .unwrap()
}

fn demo_engine() -> Engine {
    let (graph, qlayers, calibration) = demo_model(2, 7);
    let mut engine = Engine::new();
    engine.register_synthetic("demo", graph, qlayers, calibration);
    engine
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ampq_staged_{tag}_{}", std::process::id()))
}

#[test]
fn full_grid_sweep_costs_one_calibration_and_one_measurement() {
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    let taus = paper_tau_grid();
    let plans = planner
        .sweep(&Objective::ALL, &Strategy::ALL, &taus, 0)
        .unwrap();
    assert_eq!(plans.len(), 3 * 3 * taus.len());

    // The acceptance criterion: the whole grid ran off ONE pass per stage.
    let c = engine.counters();
    assert_eq!(c.calibration_passes, 1, "sweep must calibrate exactly once");
    assert_eq!(c.measurement_passes, 1, "sweep must measure exactly once");
    assert_eq!(c.partition_passes, 1);

    // Solving more plans afterwards still costs nothing.
    let planner2 = engine.planner("demo").unwrap();
    solve(&planner2, Objective::EmpiricalTime, Strategy::Ip, 0.003, 5);
    let c = engine.counters();
    assert_eq!(c.calibration_passes, 1);
    assert_eq!(c.measurement_passes, 1);
}

#[test]
fn plan_json_roundtrip_for_every_grid_cell() {
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    let plans = planner
        .sweep(&Objective::ALL, &Strategy::ALL, &paper_tau_grid(), 3)
        .unwrap();
    for plan in &plans {
        let text = plan.to_json().to_string();
        let back = Plan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(&back, plan, "round-trip mismatch for {}", plan.summary());
    }
}

#[test]
fn ip_plans_are_budget_feasible_and_monotone() {
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    for objective in Objective::ALL {
        let mut last_gain = -1.0;
        for &tau in &paper_tau_grid()[1..] {
            let plan = solve(&planner, objective, Strategy::Ip, tau, 0);
            assert!(plan.feasible, "{objective:?} tau {tau} infeasible");
            assert!(
                plan.predicted_mse <= plan.budget + 1e-12,
                "{objective:?} tau {tau}: mse {} > budget {}",
                plan.predicted_mse,
                plan.budget
            );
            assert!(plan.gain >= last_gain - 1e-9, "{objective:?} gain not monotone");
            last_gain = plan.gain;
        }
    }
}

#[test]
fn tau_zero_falls_back_to_all_bf16() {
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    for objective in Objective::ALL {
        let plan = solve(&planner, objective, Strategy::Ip, 0.0, 0);
        assert_eq!(plan.config.n_quantized(), 0, "{objective:?}");
    }
}

#[test]
fn empirical_plan_ttft_is_consistent_with_its_gain() {
    // For the ET family the plan's gain and TTFT prediction come from the
    // same measured tables: predicted_ttft == base_ttft - gain.
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    for &tau in &paper_tau_grid() {
        let plan = solve(&planner, Objective::EmpiricalTime, Strategy::Ip, tau, 0);
        let expect = plan.provenance.base_ttft_us - plan.gain;
        assert!(
            (plan.predicted_ttft_us - expect).abs() < 1e-9,
            "tau {tau}: ttft {} vs base-gain {}",
            plan.predicted_ttft_us,
            expect
        );
    }
}

#[test]
fn cold_cache_then_warm_cache_grid_is_identical_and_free() {
    let cache = temp_dir("grid");
    std::fs::remove_dir_all(&cache).ok();
    let taus = paper_tau_grid();

    let (graph, qlayers, calibration) = demo_model(2, 7);
    let mut cold = Engine::new().with_cache_dir(&cache);
    cold.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
    let cold_plans = cold
        .planner("demo")
        .unwrap()
        .sweep(&Objective::ALL, &Strategy::ALL, &taus, 0)
        .unwrap();
    assert_eq!(cold.counters().calibration_passes, 1);

    // Artifacts landed on disk in the documented layout (the measured
    // stage is keyed by the engine's device — gaudi2 by default).
    for stage in ["partitioned", "calibrated", "measured-gaudi2"] {
        let p = cache.join("demo").join(format!("{stage}.json"));
        assert!(p.exists(), "missing cache file {}", p.display());
    }

    let mut warm = Engine::new().with_cache_dir(&cache);
    warm.register_synthetic("demo", graph, qlayers, calibration);
    let warm_plans = warm
        .planner("demo")
        .unwrap()
        .sweep(&Objective::ALL, &Strategy::ALL, &taus, 0)
        .unwrap();
    let c = warm.counters();
    assert_eq!(c.partition_passes + c.calibration_passes + c.measurement_passes, 0);
    assert_eq!(c.cache_loads, 3);
    assert_eq!(warm_plans, cold_plans);

    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn random_strategy_plans_record_their_seed() {
    let mut engine = demo_engine();
    let planner = engine.planner("demo").unwrap();
    let a = solve(&planner, Objective::EmpiricalTime, Strategy::Random, 0.004, 1);
    let b = solve(&planner, Objective::EmpiricalTime, Strategy::Random, 0.004, 1);
    assert_eq!(a, b, "same seed must reproduce the same plan");
    assert_eq!(a.seed, 1);
    // Across a handful of seeds the shuffled selection must actually vary.
    let mut labels: Vec<String> = (0..6)
        .map(|seed| {
            solve(&planner, Objective::EmpiricalTime, Strategy::Random, 0.004, seed)
                .config
                .bits_label()
        })
        .collect();
    labels.sort();
    labels.dedup();
    assert!(labels.len() > 1, "random strategy should vary across seeds");
}
