//! Integration tests for the resident planning daemon: a real socket, the
//! real accept loop, and the crate's own HTTP client.
//!
//! The load-bearing assertion is BIT-identity: every daemon answer body
//! must equal the direct `PlanService::answer` serialization, at any
//! worker count — the daemon adds transport, never a different solve
//! path.  The rest covers the serving machinery itself: admission
//! overflow (503 + Retry-After), per-request deadlines (504), NDJSON
//! streaming with per-entry errors, the /metrics endpoint, and graceful
//! drain on shutdown.

use ampq::backend::DeviceProfile;
use ampq::coordinator::Strategy;
use ampq::metrics::Objective;
use ampq::plan::demo::demo_model;
use ampq::plan::service::indexed;
use ampq::plan::{Engine, PlanRequest, PlanService, ServeRequest};
use ampq::serve::client::{request as one_shot, Client};
use ampq::serve::{Daemon, ServeConfig};
use ampq::util::Json;
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// Two models ("alpha" depth 2, "beta" depth 1) staged on gaudi2 (the
/// default + its device alias) and gaudi3.  Fully deterministic, so two
/// independently built services answer bit-identically.
fn build_service() -> PlanService {
    let (ga, qa, ca) = demo_model(2, 7);
    let (gb, qb, cb) = demo_model(1, 5);
    let mut g2 = Engine::new();
    g2.register_synthetic("alpha", ga.clone(), qa.clone(), ca.clone());
    g2.register_synthetic("beta", gb.clone(), qb.clone(), cb.clone());
    let svc = PlanService::from_engine(&mut g2, &["alpha", "beta"]).unwrap();
    let mut g3 = Engine::new().with_device(DeviceProfile::gaudi3());
    g3.register_synthetic("alpha", ga, qa, ca);
    g3.register_synthetic("beta", gb, qb, cb);
    svc.register_for_device("alpha", "gaudi3", g3.planner("alpha").unwrap()).unwrap();
    svc.register_for_device("beta", "gaudi3", g3.planner("beta").unwrap()).unwrap();
    svc
}

fn devices() -> Vec<DeviceProfile> {
    vec![DeviceProfile::gaudi2(), DeviceProfile::gaudi3()]
}

/// A daemon on an ephemeral port plus the thread running it.  Dropping
/// shuts it down and joins, so a failed assertion can't leak a thread
/// that outlives its scope.
struct TestDaemon {
    daemon: Arc<Daemon>,
    addr: String,
    join: Option<std::thread::JoinHandle<()>>,
}

impl TestDaemon {
    fn start(cfg: ServeConfig) -> TestDaemon {
        Self::start_with(build_service(), cfg)
    }

    fn start_with(svc: PlanService, mut cfg: ServeConfig) -> TestDaemon {
        cfg.addr = "127.0.0.1:0".to_string();
        let daemon = Arc::new(Daemon::new(svc, devices(), cfg));
        let listener = daemon.bind().unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let d = daemon.clone();
        let join = std::thread::spawn(move || d.run(listener).unwrap());
        TestDaemon { daemon, addr, join: Some(join) }
    }

    fn stop(mut self) {
        self.daemon.handle().shutdown();
        self.join.take().unwrap().join().unwrap();
    }
}

impl Drop for TestDaemon {
    fn drop(&mut self) {
        self.daemon.handle().shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn plan_req(model: &str, tau: f64) -> ServeRequest {
    ServeRequest::new(model, PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau))
}

fn plan_body(model: &str, tau: f64) -> String {
    plan_req(model, tau).to_json().to_string()
}

#[test]
fn plan_answers_are_bit_identical_to_direct_service() {
    let oracle = build_service();
    let td = TestDaemon::start(ServeConfig::default());
    let mut c = Client::connect(&td.addr).unwrap();

    let cases = vec![
        plan_req("alpha", 0.004),
        plan_req("beta", 0.002),
        ServeRequest::new(
            "alpha",
            PlanRequest::new(Objective::EmpiricalTime)
                .with_loss_budget(0.004)
                .with_device("gaudi3"),
        ),
        plan_req("alpha", 0.003).via_frontier(),
        ServeRequest::new(
            "beta",
            PlanRequest::new(Objective::Memory).with_loss_budget(0.005),
        ),
    ];
    for req in cases {
        let body = req.to_json().to_string();
        let resp = c.request("POST", "/v1/plan", Some(body.as_str())).unwrap();
        assert_eq!(resp.status, 200, "body: {}", resp.text().unwrap());
        let expected = oracle.answer(&req).unwrap().to_string().into_bytes();
        assert_eq!(resp.body, expected, "daemon answer diverged for {body}");
    }
    td.stop();
}

#[test]
fn worker_count_does_not_change_bytes() {
    let reqs =
        vec![plan_body("alpha", 0.004), plan_body("beta", 0.001), plan_body("alpha", 0.006)];
    let mut answers: Vec<Vec<Vec<u8>>> = Vec::new();
    for workers in [1usize, 4] {
        let td = TestDaemon::start(ServeConfig { workers, ..ServeConfig::default() });
        let mut c = Client::connect(&td.addr).unwrap();
        let mut round = Vec::new();
        for body in &reqs {
            let resp = c.request("POST", "/v1/plan", Some(body.as_str())).unwrap();
            assert_eq!(resp.status, 200);
            round.push(resp.body.clone());
        }
        // The streaming frontier endpoint must be byte-stable too.
        let f = c
            .request("POST", "/v1/frontier", Some("{\"model\":\"alpha\"}"))
            .unwrap();
        assert_eq!(f.status, 200);
        round.push(f.body.clone());
        answers.push(round);
        td.stop();
    }
    assert_eq!(answers[0], answers[1], "worker count changed response bytes");
}

#[test]
fn get_endpoints_report_models_devices_and_metrics() {
    let td = TestDaemon::start(ServeConfig::default());
    let mut c = Client::connect(&td.addr).unwrap();

    let h = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(h.status, 200);
    assert_eq!(h.body, b"ok\n");

    let m = c.request("GET", "/v1/models", None).unwrap();
    assert_eq!(m.status, 200);
    let models = Json::parse(&m.text().unwrap()).unwrap();
    let names: Vec<String> = models
        .get("models")
        .unwrap()
        .arr()
        .unwrap()
        .iter()
        .map(|j| j.str().unwrap().to_string())
        .collect();
    assert_eq!(names, vec!["alpha".to_string(), "beta".to_string()]);

    let d = c.request("GET", "/v1/devices", None).unwrap();
    assert_eq!(d.status, 200);
    let parsed = Json::parse(&d.text().unwrap()).unwrap();
    let devs = parsed.get("devices").unwrap().arr().unwrap();
    assert_eq!(devs.len(), 2);
    assert_eq!(devs[1].get("name").unwrap().str().unwrap(), "gaudi3");

    // Generate one plan + one frontier sweep + one cache hit, then read
    // the counters back through the exposition endpoint.
    let body = plan_body("alpha", 0.004);
    let p = c.request("POST", "/v1/plan", Some(body.as_str())).unwrap();
    assert_eq!(p.status, 200);
    for _ in 0..2 {
        let f = c
            .request("POST", "/v1/frontier", Some("{\"model\":\"beta\"}"))
            .unwrap();
        assert_eq!(f.status, 200);
    }
    let metrics = c.request("GET", "/metrics", None).unwrap().text().unwrap();
    assert!(metrics.contains("ampq_requests_total{endpoint=\"/healthz\",status=\"200\"} 1\n"));
    assert!(metrics.contains("ampq_requests_total{endpoint=\"/v1/plan\",status=\"200\"} 1\n"));
    assert!(
        metrics.contains("ampq_requests_total{endpoint=\"/v1/frontier\",status=\"200\"} 2\n")
    );
    assert!(metrics.contains("ampq_plan_latency_us{quantile=\"0.5\"} "));
    assert!(metrics.contains("ampq_plan_latency_us{quantile=\"0.99\"} "));
    assert!(metrics.contains("ampq_plan_latency_us_count 1\n"));
    assert!(metrics.contains("ampq_frontier_latency_us_count 1\n"));
    assert!(metrics.contains("ampq_frontier_cache_hits_total 1\n"));
    assert!(metrics.contains("ampq_frontier_cache_solves_total 1\n"));
    assert!(metrics.contains("ampq_frontier_cache_entries 1\n"));
    assert!(metrics.contains("ampq_queue_rejected_total 0\n"));
    assert!(metrics.contains("ampq_queue_capacity 64\n"));

    // Routing edges: unknown path, wrong method, malformed body.
    assert_eq!(c.request("GET", "/nope", None).unwrap().status, 404);
    assert_eq!(c.request("GET", "/v1/plan", None).unwrap().status, 405);
    let bad = c.request("POST", "/v1/plan", Some("{not json")).unwrap();
    assert_eq!(bad.status, 400);
    assert_eq!(
        Json::parse(&bad.text().unwrap()).unwrap().get("kind").unwrap().str().unwrap(),
        "error"
    );
    td.stop();
}

#[test]
fn concurrent_clients_get_identical_bytes_and_cache_hits() {
    let td = TestDaemon::start(ServeConfig { workers: 4, ..ServeConfig::default() });
    let addr = td.addr.clone();
    // Every (model, device) combo exercised by every thread: 4 distinct
    // frontier keys total, everything past the first sweep a cache hit.
    let combos: Vec<String> = vec![
        "{\"model\":\"alpha\"}".into(),
        "{\"model\":\"alpha\",\"device\":\"gaudi3\"}".into(),
        "{\"model\":\"beta\"}".into(),
        "{\"model\":\"beta\",\"device\":\"gaudi3\"}".into(),
    ];
    const THREADS: usize = 8;
    let n_combos = combos.len();
    let barrier = Arc::new(Barrier::new(THREADS));
    let mut handles = Vec::new();
    for _ in 0..THREADS {
        let addr = addr.clone();
        let combos = combos.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            barrier.wait();
            let mut out = Vec::new();
            for body in &combos {
                let f = c.request("POST", "/v1/frontier", Some(body.as_str())).unwrap();
                assert_eq!(f.status, 200);
                out.push(f.body);
                let plan = plan_body("alpha", 0.004);
                let p = c.request("POST", "/v1/plan", Some(plan.as_str())).unwrap();
                assert_eq!(p.status, 200);
                out.push(p.body);
            }
            out
        }));
    }
    let results: Vec<Vec<Vec<u8>>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &results[1..] {
        assert_eq!(r, &results[0], "concurrent clients saw different bytes");
    }
    // 4 distinct frontier cells; every other lookup was a hit.
    let svc = td.daemon.service();
    assert_eq!(svc.frontier_solves(), 4);
    assert_eq!(svc.frontier_hits(), THREADS * n_combos - 4);
    assert_eq!(svc.frontier_cache_len(), 4);
    td.stop();
}

#[test]
fn queue_overflow_answers_503_with_retry_after() {
    // One worker, tiny queue, 100ms per job: a synchronized burst has to
    // overflow admission — and the daemon must keep serving afterwards.
    let td = TestDaemon::start(ServeConfig {
        workers: 1,
        queue_depth: 2,
        debug_delay: Duration::from_millis(100),
        ..ServeConfig::default()
    });
    const CLIENTS: usize = 12;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let mut handles = Vec::new();
    for _ in 0..CLIENTS {
        let addr = td.addr.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let body = plan_body("alpha", 0.004);
            barrier.wait();
            let resp = c.request("POST", "/v1/plan", Some(body.as_str())).unwrap();
            if resp.status == 503 {
                assert_eq!(resp.header("retry-after"), Some("1"));
            }
            resp.status
        }));
    }
    let statuses: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let ok = statuses.iter().filter(|&&s| s == 200).count();
    let rejected = statuses.iter().filter(|&&s| s == 503).count();
    assert_eq!(ok + rejected, CLIENTS, "unexpected statuses: {statuses:?}");
    assert!(ok >= 1, "no request survived the burst: {statuses:?}");
    assert!(rejected >= 1, "burst never overflowed the queue: {statuses:?}");
    assert_eq!(td.daemon.metrics().rejected() as usize, rejected);
    // No deadlock, no panic: the daemon still answers.
    assert_eq!(one_shot(&td.addr, "GET", "/healthz", None).unwrap().status, 200);
    td.stop();
}

/// A mock HTTP server for retry-path tests: scripted statuses, one
/// connection per response, counts attempts.
fn mock_server(
    responses: Vec<&'static str>,
) -> (String, Arc<std::sync::atomic::AtomicUsize>, std::thread::JoinHandle<()>) {
    use std::io::{Read, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let hits = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let h = hits.clone();
    let join = std::thread::spawn(move || {
        for resp in responses {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 4096];
            let _ = s.read(&mut buf); // request head; content ignored
            h.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            s.write_all(resp.as_bytes()).unwrap();
        }
    });
    (addr, hits, join)
}

const BUSY: &str = "HTTP/1.1 503 Service Unavailable\r\nRetry-After: 0\r\nContent-Length: 0\r\nConnection: close\r\n\r\n";
const BUSY_NO_HINT: &str =
    "HTTP/1.1 503 Service Unavailable\r\nContent-Length: 0\r\nConnection: close\r\n\r\n";
const OK: &str = "HTTP/1.1 200 OK\r\nContent-Length: 3\r\nConnection: close\r\n\r\nok\n";

#[test]
fn client_retries_503_until_success_within_budget() {
    use ampq::serve::client::{request_with_retry, RetryPolicy};
    let (addr, hits, join) = mock_server(vec![BUSY, BUSY, OK]);
    let policy = RetryPolicy { budget: 3, max_wait: Duration::from_millis(50) };
    let r = request_with_retry(&addr, "POST", "/v1/plan", Some("{}"), policy).unwrap();
    assert_eq!(r.response.status, 200);
    assert_eq!(r.attempts, 3, "two 503s then success");
    assert_eq!(hits.load(std::sync::atomic::Ordering::SeqCst), 3);
    join.join().unwrap();
}

#[test]
fn client_retry_budget_is_capped() {
    use ampq::serve::client::{request_with_retry, RetryPolicy};
    // Budget 2 = at most 3 attempts total, even against a server that
    // never stops saying 503.
    let (addr, hits, join) = mock_server(vec![BUSY, BUSY, BUSY]);
    let policy = RetryPolicy { budget: 2, max_wait: Duration::from_millis(50) };
    let r = request_with_retry(&addr, "POST", "/v1/plan", Some("{}"), policy).unwrap();
    assert_eq!(r.response.status, 503, "exhausted budget returns the last 503");
    assert_eq!(r.attempts, 3);
    assert_eq!(hits.load(std::sync::atomic::Ordering::SeqCst), 3);
    join.join().unwrap();
}

#[test]
fn client_does_not_retry_a_hintless_503() {
    use ampq::serve::client::{request_with_retry, RetryPolicy};
    let (addr, hits, join) = mock_server(vec![BUSY_NO_HINT]);
    let policy = RetryPolicy { budget: 5, max_wait: Duration::from_millis(50) };
    let r = request_with_retry(&addr, "POST", "/v1/plan", Some("{}"), policy).unwrap();
    assert_eq!(r.response.status, 503);
    assert_eq!(r.attempts, 1, "no Retry-After header, no retry");
    assert_eq!(hits.load(std::sync::atomic::Ordering::SeqCst), 1);
    join.join().unwrap();
}

#[test]
fn retrying_clients_ride_out_a_queue_overflow() {
    use ampq::serve::client::{request_with_retry, RetryPolicy};
    // Same overload shape as queue_overflow_answers_503_with_retry_after,
    // but every client retries on the server's Retry-After hint — so ALL
    // of them must eventually land a 200.
    let td = TestDaemon::start(ServeConfig {
        workers: 1,
        queue_depth: 2,
        debug_delay: Duration::from_millis(50),
        ..ServeConfig::default()
    });
    const CLIENTS: usize = 8;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let mut handles = Vec::new();
    for _ in 0..CLIENTS {
        let addr = td.addr.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let body = plan_body("alpha", 0.004);
            let policy = RetryPolicy { budget: 100, max_wait: Duration::from_millis(25) };
            barrier.wait();
            request_with_retry(&addr, "POST", "/v1/plan", Some(body.as_str()), policy)
                .unwrap()
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &results {
        assert_eq!(r.response.status, 200, "retries must ride out the burst");
    }
    let attempts: usize = results.iter().map(|r| r.attempts).sum();
    assert!(attempts >= CLIENTS);
    assert_eq!(td.daemon.metrics().rejected() as usize, attempts - CLIENTS);
    td.stop();
}

#[test]
fn expired_requests_answer_504() {
    let td = TestDaemon::start(ServeConfig {
        workers: 1,
        request_timeout: Duration::from_millis(50),
        debug_delay: Duration::from_millis(600),
        ..ServeConfig::default()
    });
    let body = plan_body("alpha", 0.004);
    let resp = one_shot(&td.addr, "POST", "/v1/plan", Some(body.as_str())).unwrap();
    assert_eq!(resp.status, 504);
    assert!(td.daemon.metrics().timeouts() >= 1);
    td.stop();
}

#[test]
fn batch_plan_streams_indexed_lines_with_per_request_errors() {
    let oracle = build_service();
    let td = TestDaemon::start(ServeConfig::default());
    let batch = format!(
        "[{},{},{},{}]",
        plan_body("alpha", 0.004),
        plan_body("nope", 0.004),               // unknown model
        "{\"objective\":\"et\",\"tau\":0.004}", // missing model field
        plan_body("beta", 0.002),
    );
    let resp = one_shot(&td.addr, "POST", "/v1/plan", Some(batch.as_str())).unwrap();
    assert_eq!(resp.status, 200);
    let lines = resp.lines().unwrap();
    assert_eq!(lines.len(), 6, "header + 4 entries + footer: {lines:?}");

    let header = Json::parse(&lines[0]).unwrap();
    assert_eq!(header.get("kind").unwrap().str().unwrap(), "batch");
    assert_eq!(header.get("n").unwrap().usize().unwrap(), 4);

    // Entries arrive in request order, index-stamped; good ones are the
    // oracle's answers byte for byte.
    for (i, expect_ok) in [(0usize, true), (1, false), (2, false), (3, true)] {
        let line = Json::parse(&lines[1 + i]).unwrap();
        assert_eq!(line.get("index").unwrap().usize().unwrap(), i);
        if expect_ok {
            let req = if i == 0 { plan_req("alpha", 0.004) } else { plan_req("beta", 0.002) };
            let expected = indexed(i, oracle.answer(&req).unwrap());
            assert_eq!(lines[1 + i], expected.to_string());
        } else {
            assert_eq!(line.get("kind").unwrap().str().unwrap(), "error");
            assert!(!line.get("error").unwrap().str().unwrap().is_empty());
        }
    }
    let footer = Json::parse(&lines[5]).unwrap();
    assert_eq!(footer.get("kind").unwrap().str().unwrap(), "done");
    assert_eq!(footer.get("errors").unwrap().usize().unwrap(), 2);
    td.stop();
}

#[test]
fn frontier_streams_knots_matching_the_cached_curve() {
    let oracle = build_service();
    let td = TestDaemon::start(ServeConfig::default());
    let resp =
        one_shot(&td.addr, "POST", "/v1/frontier", Some("{\"model\":\"alpha\"}")).unwrap();
    assert_eq!(resp.status, 200);
    let lines = resp.lines().unwrap();
    let f = oracle.frontier_for("alpha", None, Objective::EmpiricalTime, Strategy::Ip).unwrap();

    let header = Json::parse(&lines[0]).unwrap();
    assert_eq!(header.get("kind").unwrap().str().unwrap(), "frontier_header");
    assert_eq!(header.get("model").unwrap().str().unwrap(), "alpha");
    assert_eq!(header.get("device").unwrap().str().unwrap(), "gaudi2");
    assert_eq!(header.get("points").unwrap().usize().unwrap(), f.points.len());
    assert_eq!(lines.len(), f.points.len() + 2, "header + knots + footer");
    for (k, p) in f.points.iter().enumerate() {
        let knot = Json::parse(&lines[1 + k]).unwrap();
        assert_eq!(knot.get("kind").unwrap().str().unwrap(), "knot");
        assert_eq!(knot.get("i").unwrap().usize().unwrap(), k);
        assert_eq!(knot.get("tau").unwrap().f64().unwrap(), p.tau);
        assert_eq!(knot.get("gain").unwrap().f64().unwrap(), p.gain);
    }
    let footer = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(footer.get("kind").unwrap().str().unwrap(), "frontier_done");

    // Batch form: per-entry index stamps, errors inline, stream completes.
    let resp = one_shot(
        &td.addr,
        "POST",
        "/v1/frontier",
        Some("[{\"model\":\"alpha\"},{\"model\":\"nope\"}]"),
    )
    .unwrap();
    assert_eq!(resp.status, 200);
    let lines = resp.lines().unwrap();
    assert_eq!(lines.len(), 1 + (f.points.len() + 2) + 1 + 1);
    let header = Json::parse(&lines[1]).unwrap();
    assert_eq!(header.get("kind").unwrap().str().unwrap(), "frontier_header");
    assert_eq!(header.get("index").unwrap().usize().unwrap(), 0);
    let err = Json::parse(&lines[lines.len() - 2]).unwrap();
    assert_eq!(err.get("kind").unwrap().str().unwrap(), "error");
    assert_eq!(err.get("index").unwrap().usize().unwrap(), 1);
    let footer = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(footer.get("errors").unwrap().usize().unwrap(), 1);
    td.stop();
}

#[test]
fn oversized_bodies_answer_413() {
    let td = TestDaemon::start(ServeConfig {
        limits: ampq::serve::http::Limits {
            max_body_bytes: 1024,
            ..ampq::serve::http::Limits::default()
        },
        ..ServeConfig::default()
    });
    let big = format!("{{\"model\":\"{}\"}}", "x".repeat(4096));
    let resp = one_shot(&td.addr, "POST", "/v1/plan", Some(big.as_str())).unwrap();
    assert_eq!(resp.status, 413);
    td.stop();
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let td = TestDaemon::start(ServeConfig {
        workers: 1,
        debug_delay: Duration::from_millis(300),
        ..ServeConfig::default()
    });
    let addr = td.addr.clone();
    let slow_addr = addr.clone();
    let slow = std::thread::spawn(move || {
        let body = plan_body("alpha", 0.004);
        one_shot(&slow_addr, "POST", "/v1/plan", Some(body.as_str())).unwrap()
    });
    // Let the slow request get admitted, then pull the plug.
    std::thread::sleep(Duration::from_millis(100));
    td.daemon.handle().shutdown();
    let resp = slow.join().unwrap();
    assert_eq!(resp.status, 200, "in-flight request must complete through a drain");
    // run() returns (stop() joins the thread), after which the port is dark.
    let daemon = td.daemon.clone();
    td.stop();
    assert!(daemon.handle().is_shutdown());
    assert!(
        one_shot(&addr, "GET", "/healthz", None).is_err(),
        "daemon kept serving after shutdown"
    );
}
