//! Wire-schema symmetry tests for the hand-rolled JSON codecs — the
//! dynamic counterpart of lint rule D5.  Every field an encoder writes
//! must round-trip, every field a decoder requires must reject when
//! absent, and unknown extra fields must be tolerated consistently
//! (additive schema evolution) across the level / MCKP / envelope codecs.

use ampq::dist::protocol::{
    err_response, level_from_json, level_to_json, mckp_from_json, mckp_to_json, msg_id,
    ok_response, request,
};
use ampq::solver::parametric::LevelSoa;
use ampq::solver::problem::gen::random_multi;
use ampq::util::{Json, Rng};

/// Remove `key` from an object, panicking if it was not present (so the
/// test fails loudly if the schema drifts under it).
fn without(j: &Json, key: &str) -> Json {
    match j {
        Json::Obj(kv) => {
            let filtered: Vec<(String, Json)> =
                kv.iter().filter(|(k, _)| k != key).cloned().collect();
            assert_eq!(filtered.len() + 1, kv.len(), "field '{key}' missing from encoder output");
            Json::Obj(filtered)
        }
        _ => panic!("expected an object"),
    }
}

fn with_extra(j: &Json, key: &str) -> Json {
    match j {
        Json::Obj(kv) => {
            let mut kv = kv.clone();
            kv.push((key.to_string(), Json::Str("ignored".into())));
            Json::Obj(kv)
        }
        _ => panic!("expected an object"),
    }
}

fn sample_level() -> LevelSoa {
    let mut level = LevelSoa::new(2);
    level.push(0.125, &[1.0, 2.0], u32::MAX, 0);
    level.push(3.5, &[4.0, 5.0], 0, 1);
    level
}

#[test]
fn level_decoder_rejects_each_missing_field() {
    let j = level_to_json(&sample_level(), 0, 2);
    assert!(level_from_json(&j).is_ok(), "baseline encoding must decode");
    for key in ["dims", "g", "c", "p", "ch"] {
        let crippled = without(&j, key);
        assert!(
            level_from_json(&crippled).is_err(),
            "level_from_json accepted a frame missing '{key}'"
        );
    }
}

#[test]
fn level_decoder_tolerates_unknown_fields() {
    let j = with_extra(&level_to_json(&sample_level(), 0, 2), "future_field");
    let back = level_from_json(&j).expect("unknown fields are additive, not fatal");
    assert_eq!(back.len(), 2);
}

#[test]
fn level_decoder_rejects_inconsistent_shapes() {
    let j = level_to_json(&sample_level(), 0, 2);
    let broken = match &j {
        Json::Obj(kv) => Json::Obj(
            kv.iter()
                .map(|(k, v)| {
                    if k == "p" {
                        (k.clone(), Json::Arr(vec![Json::Num(0.0)])) // 1 parent, 2 gains
                    } else {
                        (k.clone(), v.clone())
                    }
                })
                .collect(),
        ),
        _ => unreachable!(),
    };
    assert!(level_from_json(&broken).is_err());
}

#[test]
fn mckp_decoder_rejects_each_missing_field() {
    let mut rng = Rng::new(7);
    let p = random_multi(&mut rng, 4, 3, 2);
    let j = mckp_to_json(&p);
    assert!(mckp_from_json(&j).is_ok());
    for key in ["gains", "costs", "budgets"] {
        assert!(
            mckp_from_json(&without(&j, key)).is_err(),
            "mckp_from_json accepted a frame missing '{key}'"
        );
    }
    // Nested cost-dimension objects carry the same contract.
    if let Json::Obj(kv) = &j {
        let mut kv = kv.clone();
        for (k, v) in kv.iter_mut() {
            if k == "costs" {
                if let Json::Arr(dims) = v {
                    dims[0] = without(&dims[0], "table");
                }
            }
        }
        assert!(mckp_from_json(&Json::Obj(kv)).is_err(), "cost dim without 'table' accepted");
    }
}

#[test]
fn mckp_random_instances_roundtrip_exactly() {
    let mut rng = Rng::new(0xA11CE);
    for _ in 0..25 {
        let p = random_multi(&mut rng, 6, 4, 3);
        let text = mckp_to_json(&p).to_string();
        let back = mckp_from_json(&Json::parse(&text).expect("valid JSON")).expect("roundtrip");
        assert_eq!(back.gains, p.gains);
        assert_eq!(back.budgets, p.budgets);
        assert_eq!(back.costs.len(), p.costs.len());
        for (a, b) in back.costs.iter().zip(&p.costs) {
            assert_eq!(a, b);
        }
        // Unknown-field tolerance is uniform across codecs.
        assert!(mckp_from_json(&with_extra(&mckp_to_json(&p), "vendor_ext")).is_ok());
    }
}

#[test]
fn envelope_fields_are_symmetric() {
    let req = request(42, "expand_chunk", vec![("lo".into(), Json::Num(0.0))]);
    assert_eq!(msg_id(&req).unwrap(), 42);
    assert_eq!(req.get("kind").unwrap().str().unwrap(), "expand_chunk");
    assert_eq!(req.get("lo").unwrap().f64().unwrap(), 0.0);

    let ok = ok_response(42, Json::Str("done".into()));
    assert_eq!(msg_id(&ok).unwrap(), 42);
    assert!(ok.get("ok").unwrap().bool().unwrap());
    assert_eq!(ok.get("result").unwrap().str().unwrap(), "done");

    let err = err_response(43, "nope");
    assert_eq!(msg_id(&err).unwrap(), 43);
    assert!(!err.get("ok").unwrap().bool().unwrap());
    assert_eq!(err.get("error").unwrap().str().unwrap(), "nope");

    // A frame without an id is unroutable and must be rejected, not
    // defaulted — the same strictness the level/mckp decoders apply.
    assert!(msg_id(&Json::Obj(vec![])).is_err());
}

#[test]
fn envelope_ids_survive_u64_range() {
    for id in [0u64, 1, u32::MAX as u64, u64::MAX] {
        let req = request(id, "ping", vec![]);
        let text = req.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(msg_id(&back).unwrap(), id, "id {id} corrupted on the wire");
    }
}
