// lint: path src/solver/fixture_clean.rs
//! Control fixture: equivalent code written the approved way.  `ampq lint`
//! must exit zero on this file.

pub fn sort_gains(v: &mut [f64]) {
    v.sort_by(|a, b| a.total_cmp(b));
}
