// lint: path src/serve/fixture_d4.rs
//! Seeded D4 violation: panic on a user-reachable request path.  A bad
//! request body must map to an error response, never to a daemon abort.

use std::sync::Mutex;

pub fn parse_tau(body: &str) -> f64 {
    body.trim().parse().unwrap()
}

/// NOT a violation: a poisoned lock is itself evidence of a prior panic,
/// so the `.expect` is a witness, not a new panic path (the D4 carve-out).
pub fn peek(m: &Mutex<Vec<u64>>) -> usize {
    m.lock().expect("lock poisoned").len()
}
