// lint: path src/solver/fixture_d1.rs
//! Seeded D1 violation: float ordering through `partial_cmp().unwrap()`.
//! NaN panics here; `f64::total_cmp` is the deterministic total order.

pub fn sort_gains(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
