// lint: path src/plan/fixture_d3.rs
//! Seeded D3 violation: wall clock outside `obs/`, `timing/`, `serve/`.
//! Clock reads on the planning path make output depend on machine load.

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
