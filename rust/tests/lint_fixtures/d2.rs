// lint: path src/report/fixture_d2.rs
//! Seeded D2 violation: hash-order iteration feeding serialized output.
//! HashMap iteration order varies across runs and toolchains; serialized
//! bytes built from it break the bit-identical-output contract.

use crate::util::Json;
use std::collections::HashMap;

pub fn emit(metrics: &HashMap<String, f64>) -> Json {
    let mut rows = Vec::new();
    for (k, v) in metrics.iter() {
        rows.push((k.clone(), Json::Num(*v)));
    }
    Json::Obj(rows)
}

/// Same shape, but audited: the caller inserts in key order.
pub fn emit_presorted(counters: &HashMap<String, u64>) -> Json {
    let mut rows = Vec::new();
    // lint: sorted upstream: caller guarantees insertion in key order
    for (k, v) in counters.iter() {
        rows.push((k.clone(), Json::Num(*v as f64)));
    }
    Json::Obj(rows)
}
