// lint: path src/dist/fixture_d5.rs
//! Seeded D5 violation: encoder/decoder field-name asymmetry.  The
//! encoder writes `y`; the decoder never reads it — round-trips silently
//! lose data.

use crate::util::Json;
use anyhow::Result;

pub fn point_to_json(x: f64, y: f64) -> Json {
    Json::Obj(vec![
        ("x".into(), Json::Num(x)),
        ("y".into(), Json::Num(y)),
    ])
}

pub fn point_from_json(j: &Json) -> Result<(f64, f64)> {
    let x = j.get("x")?.f64()?;
    Ok((x, 0.0))
}
