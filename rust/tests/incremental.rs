//! Acceptance suite for the incremental frontier re-solve path
//! (`FrontierDp::solve_delta` and its `Planner`/`PlanService` plumbing):
//!
//! * a warm arena re-solve after mutating ONE group's gain table is
//!   bit-identical to a from-scratch sweep, and re-merges exactly the
//!   dirty suffix of the group chain;
//! * tau-range (primary budget) and memory-cap changes reuse EVERY
//!   committed level — only the feasibility filter re-runs;
//! * all of the above at 1 and 4 threads, single- and multi-constraint
//!   (`--threads N ≡ --threads 1` bit-identity extends to warm arenas);
//! * `Planner::frontier_delta` serves the same curve as
//!   `Planner::frontier` and reports full reuse on a repeat solve.
//!
//! Instance sizes are chosen so the budget-free DP levels can never
//! exceed the dominance state caps (4^5 = 1024 < 2048 multi,
//! 5^6 = 15625 < 32768 single): the arena never bails to the classic
//! sweep, so the delta accounting asserted here is deterministic.

use ampq::coordinator::Strategy;
use ampq::exec::{ExecCfg, ExecPool};
use ampq::metrics::Objective;
use ampq::plan::demo::demo_model;
use ampq::plan::Engine;
use ampq::solver::parametric::{self, FrontierDp, ParametricCurve};
use ampq::solver::problem::gen::{random, random_multi};
use ampq::solver::Mckp;
use ampq::util::Rng;

/// A random instance sized to stay under the DP state caps even with the
/// suffix-budget filter off (see module docs).
fn instance(rng: &mut Rng, dims: usize) -> Mckp {
    if dims == 1 {
        random(rng, 6, 5)
    } else {
        random_multi(rng, 5, 4, dims)
    }
}

/// Bitwise curve equality with a labelled panic (assert_eq's Debug dump
/// of two full curves is unreadable; the derived PartialEq is exact float
/// equality, which is the contract here).
fn assert_same_curve(a: &ParametricCurve, b: &ParametricCurve, label: &str) {
    assert_eq!(a, b, "{label}: warm arena curve differs from the from-scratch sweep");
}

#[test]
fn warm_resolve_of_an_unchanged_instance_reuses_everything() {
    for threads in [1usize, 4] {
        let pool = ExecPool::new(ExecCfg::new(threads));
        let mut rng = Rng::new(0x1DE2_0001);
        for trial in 0..30 {
            let dims = 1 + (trial % 2);
            let p = instance(&mut rng, dims);
            let oracle = parametric::frontier_with(&p, &ExecPool::sequential());
            let mut dp = FrontierDp::default();
            let (cold, d0) = dp.solve_delta(&p, &pool);
            assert_same_curve(&cold, &oracle, "cold");
            assert!(d0.full_solve, "trial {trial}: cold arena must report a full solve");
            let (warm, d1) = dp.solve_delta(&p, &pool);
            assert_same_curve(&warm, &oracle, "warm");
            assert!(!d1.full_solve, "trial {trial} threads {threads}");
            assert_eq!(d1.solved_groups, 0, "trial {trial}: nothing changed");
            assert_eq!(d1.reused_levels, p.n_groups(), "trial {trial}");
            assert!(d1.reused_states > 0, "trial {trial}");
        }
    }
}

#[test]
fn mutating_one_groups_gain_table_resolves_only_the_dirty_suffix() {
    for threads in [1usize, 4] {
        let pool = ExecPool::new(ExecCfg::new(threads));
        let mut rng = Rng::new(0xD127_0002 ^ threads as u64);
        for dims in [1usize, 2] {
            let mut p = instance(&mut rng, dims);
            let n = p.n_groups();
            let mut dp = FrontierDp::default();
            dp.solve_delta(&p, &pool);
            for trial in 0..(2 * n) {
                let j = trial % n;
                let last = p.gains[j].len() - 1;
                p.gains[j][last] += 0.25;
                let oracle = parametric::frontier_with(&p, &ExecPool::sequential());
                let (curve, delta) = dp.solve_delta(&p, &pool);
                assert_same_curve(
                    &curve,
                    &oracle,
                    &format!("dims {dims} threads {threads} trial {trial}"),
                );
                assert!(!delta.full_solve, "dims {dims} trial {trial}");
                assert_eq!(
                    delta.reused_levels, j,
                    "dims {dims} trial {trial}: group {j} was mutated, so every level \
                     before it must be reused as-is"
                );
                assert_eq!(delta.solved_groups, n - j, "dims {dims} trial {trial}");
            }
        }
    }
}

#[test]
fn budget_and_memory_cap_changes_reuse_every_committed_level() {
    for threads in [1usize, 4] {
        let pool = ExecPool::new(ExecCfg::new(threads));
        let mut rng = Rng::new(0xB0D6_0003);
        for dims in [1usize, 2] {
            let p0 = instance(&mut rng, dims);
            let base = p0.budgets.clone();
            let mut dp = FrontierDp::default();
            dp.solve_delta(&p0, &pool);
            // Tau-range moves (primary budget) and, on the multi-constraint
            // instance, memory-cap moves (second budget): neither touches a
            // gain/cost table, so the whole committed chain re-filters
            // without a single group re-merge.
            for (trial, scale) in [0.0f64, 0.35, 1.0, 2.5].into_iter().enumerate() {
                for dim in 0..dims {
                    let mut p = p0.clone();
                    p.budgets[dim] = base[dim] * scale;
                    let oracle = parametric::frontier_with(&p, &ExecPool::sequential());
                    let (curve, delta) = dp.solve_delta(&p, &pool);
                    assert_same_curve(
                        &curve,
                        &oracle,
                        &format!("dims {dims} threads {threads} trial {trial} dim {dim}"),
                    );
                    assert!(!delta.full_solve, "dims {dims} trial {trial} dim {dim}");
                    assert_eq!(delta.solved_groups, 0, "dims {dims} trial {trial} dim {dim}");
                    assert_eq!(
                        delta.reused_levels,
                        p.n_groups(),
                        "dims {dims} trial {trial} dim {dim}"
                    );
                }
            }
        }
    }
}

#[test]
fn planner_frontier_delta_matches_frontier_and_reports_reuse() {
    let (graph, qlayers, calibration) = demo_model(2, 7);
    let mut engine = Engine::new().with_threads(2);
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let planner = engine.planner("demo").unwrap();
    for objective in [Objective::EmpiricalTime, Objective::Memory] {
        let first = planner.frontier(objective, Strategy::Ip).unwrap();
        let (second, delta) = planner.frontier_delta(objective, Strategy::Ip).unwrap();
        assert_eq!(first, second, "{objective:?}: warm re-solve must reproduce the curve");
        assert!(!delta.full_solve, "{objective:?}: the first solve committed the arena");
        assert_eq!(delta.solved_groups, 0, "{objective:?}");
        let stats = planner.frontier_dp_stats(objective);
        assert!(stats.peak_live_states > 0, "{objective:?}");
        assert!(stats.arena_bytes > 0, "{objective:?}");
    }
    // Non-IP strategies stay on the bisection sweep and say so.
    let (f, delta) = planner
        .frontier_delta(Objective::EmpiricalTime, Strategy::Random)
        .unwrap();
    assert!(delta.full_solve);
    assert_eq!(delta.solved_groups, 0);
    assert!(!f.points.is_empty());
}
