//! Integration tests over the real artifacts: the full Algorithm-1 staging
//! (PJRT runtime + partition + calibration + simulator + IP) and the
//! paper's §3.2 validation claims at test scale, on the staged planning
//! API (`plan::Engine` / `plan::Planner`).
//!
//! Requires `make artifacts` to have produced artifacts/, plus real PJRT
//! bindings in place of the vendored xla stub.

use ampq::backend::DeviceProfile;
use ampq::coordinator::{optimize, select_config, Strategy};
use ampq::exec::ExecPool;
use ampq::evalharness::{evaluate, load_all_tasks};
use ampq::gaudisim::{MpConfig, Simulator};
use ampq::graph::Graph;
use ampq::metrics::Objective;
use ampq::model::ModelInfo;
use ampq::numerics::{Format, PAPER_FORMATS};
use ampq::plan::{Engine, Partitioned, Planner};
use ampq::runtime::{FwdMode, ModelRuntime};
use ampq::sensitivity::validate::{draw_pscale, measured_loss_mse};
use ampq::util::Rng;
use std::path::PathBuf;

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The noise-free gaudi2 testbed the validation checks measure on.
fn quiet_device() -> DeviceProfile {
    let mut d = DeviceProfile::gaudi2();
    d.noise_std = 0.0;
    d
}

/// PJRT handles are not Send/Sync and XLA compilation is expensive, so the
/// runtime-dependent checks share ONE staged engine inside a single #[test]
/// and run sequentially as sub-checks.
#[test]
#[ignore = "requires real PJRT bindings + AOT artifacts (vendored xla stub cannot execute)"]
fn full_pipeline_integration() {
    let mut engine = Engine::new()
        .with_artifacts_root(root())
        .with_fwd_mode(FwdMode::Ref);
    // Stage everything up front (&mut engine), then borrow the runtime for
    // the rest of the checks.
    let info = engine.info("tiny-s").expect("manifest (run `make artifacts` first)");
    let graph = engine.graph("tiny-s").unwrap();
    let part = engine.partitioned("tiny-s").unwrap();
    let planner = engine.planner("tiny-s").expect("staging (PJRT calibration)");
    let mr = engine.runtime("tiny-s").expect("PJRT runtime");

    check_partition_matches_paper_fig6(&part, &info);
    check_sensitivity_spread(&planner, &info);
    check_predicted_loss_mse_tracks_measured(&planner, &info, mr);
    check_group_gains_additive(&graph, &part, &info);
    check_ip_dominates_baselines(&planner);
    check_budget_respected(&planner);
    check_memory_family_skips_bgemm(&planner, &info);
    check_evaluation(&info, mr);
    check_tau_zero(&planner);
    check_wall_clock(&info, mr);
}

fn check_partition_matches_paper_fig6(part: &Partitioned, info: &ModelInfo) {
    // Per block: V1 = 5-layer attention, V2 = o_proj, V3 = {gate, up},
    // V4 = down_proj; plus the final lm_head group (paper Fig. 6).
    let sizes: Vec<usize> = part.partition.groups.iter().map(|g| g.len()).collect();
    let expected: Vec<usize> = (0..info.blocks)
        .flat_map(|_| vec![5, 1, 2, 1])
        .chain(std::iter::once(1))
        .collect();
    assert_eq!(sizes, expected);
    // First group is exactly the attention five.
    let names: Vec<&str> = part.partition.groups[0]
        .qidxs
        .iter()
        .map(|&q| info.qlayers[q].name.as_str())
        .collect();
    assert_eq!(
        names,
        vec!["blk0.q_proj", "blk0.k_proj", "blk0.v_proj", "blk0.qk_matmul", "blk0.av_matmul"]
    );
}

fn check_sensitivity_spread(planner: &Planner, info: &ModelInfo) {
    let s = &planner.calibration().s;
    assert_eq!(s.len(), info.n_qlayers);
    assert!(s.iter().all(|&x| x > 0.0));
    let max = s.iter().cloned().fold(f64::MIN, f64::max);
    let min = s.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max / min > 3.0, "sensitivity spread too small: {min}..{max}");
}

fn check_predicted_loss_mse_tracks_measured(planner: &Planner, info: &ModelInfo, mr: &ModelRuntime) {
    // Paper Fig. 3a at test scale: prediction within an order of magnitude
    // and correctly ordered between BF16 and FP8.
    let calib = info.load_calib(&root()).unwrap();
    let mut rng = Rng::new(5);
    let mut ratios = Vec::new();
    for fmt in [Format::Bf16, Format::Fp8E4m3] {
        let cfg = MpConfig::uniform(info.n_qlayers, fmt);
        let pred = planner.calibration().loss_mse(&cfg);
        let meas = measured_loss_mse(mr, &calib, &cfg, 2, 0.02, &mut rng).unwrap();
        assert!(meas > 0.0);
        ratios.push(pred / meas);
    }
    for r in &ratios {
        assert!(*r > 0.05 && *r < 20.0, "prediction ratio {r} out of range");
    }
    // FP8 must measure much larger than BF16.
    let cfg8 = MpConfig::uniform(info.n_qlayers, Format::Fp8E4m3);
    let cfg16 = MpConfig::all_bf16(info.n_qlayers);
    let m8 = measured_loss_mse(mr, &calib, &cfg8, 2, 0.02, &mut rng).unwrap();
    let m16 = measured_loss_mse(mr, &calib, &cfg16, 2, 0.02, &mut rng).unwrap();
    assert!(m8 > m16 * 10.0, "fp8 {m8} vs bf16 {m16}");
}

fn check_group_gains_additive(graph: &Graph, part: &Partitioned, info: &ModelInfo) {
    // Paper Fig. 3b / §3.2: group-additive prediction matches direct
    // measurement (noise-free simulator).
    let device = quiet_device();
    let src = ampq::timing::SimTtft::for_device(graph, &device, 0, 1);
    let tm =
        ampq::timing::measure_groups(&src, &part.partition, &PAPER_FORMATS, &ExecPool::sequential())
            .unwrap();
    let sim = Simulator::for_device(graph, &device);
    for (tag, cfg) in [
        ("all-fp8", MpConfig::uniform(info.n_qlayers, Format::Fp8E4m3)),
        ("half", {
            let mut c = MpConfig::all_bf16(info.n_qlayers);
            for l in 0..info.n_qlayers / 2 {
                c.set(l, Format::Fp8E4m3);
            }
            c
        }),
    ] {
        let direct = sim.makespan(&cfg);
        let predicted = tm.predict_ttft(&cfg);
        let rel = (direct - predicted).abs() / direct;
        assert!(rel < 0.05, "{tag}: direct {direct} vs predicted {predicted} (rel {rel})");
    }
}

fn check_ip_dominates_baselines(planner: &Planner) {
    let tm = planner.measurements();
    let calibration = planner.calibration();
    let family = planner.family(Objective::EmpiricalTime);
    for tau in [0.002, 0.004, 0.007] {
        let ip = optimize(&family.groups, calibration, tau, &ExecPool::sequential()).unwrap();
        for strategy in [Strategy::Random, Strategy::Prefix] {
            for seed in 0..3 {
                let cfg =
                    select_config(family, strategy, calibration, tau, seed, &ExecPool::sequential())
                        .unwrap();
                let baseline_gain = tm.predict_gain(&cfg);
                assert!(
                    ip.solution.gain >= baseline_gain - 1e-6,
                    "tau {tau}: IP {} < {} {baseline_gain}",
                    ip.solution.gain,
                    strategy.name()
                );
            }
        }
    }
}

fn check_budget_respected(planner: &Planner) {
    let calibration = planner.calibration();
    for objective in [Objective::EmpiricalTime, Objective::TheoreticalTime, Objective::Memory] {
        let family = planner.family(objective);
        for tau in [0.001, 0.003, 0.006] {
            let out = optimize(&family.groups, calibration, tau, &ExecPool::sequential()).unwrap();
            if out.solution.feasible {
                assert!(
                    out.predicted_mse <= calibration.budget(tau) + 1e-12,
                    "{} tau {tau}: mse {} > budget {}",
                    objective.name(),
                    out.predicted_mse,
                    calibration.budget(tau)
                );
            }
        }
    }
}

fn check_memory_family_skips_bgemm(planner: &Planner, info: &ModelInfo) {
    let family = planner.family(Objective::Memory);
    let out =
        optimize(&family.groups, planner.calibration(), 0.01, &ExecPool::sequential()).unwrap();
    for (l, q) in info.qlayers.iter().enumerate() {
        if q.kind == ampq::model::LayerKind::Bgemm {
            assert_eq!(out.config.get(l), Format::Bf16, "{}", q.name);
        }
    }
    // ...but with a generous budget it quantizes every linear layer.
    let n_linear = info
        .qlayers
        .iter()
        .filter(|q| q.kind == ampq::model::LayerKind::Linear)
        .count();
    assert_eq!(out.config.n_quantized(), n_linear);
}

fn check_evaluation(info: &ModelInfo, mr: &ModelRuntime) {
    let tasks = load_all_tasks(&root(), info).unwrap();
    let nq = info.n_qlayers;
    let bf16 = MpConfig::all_bf16(nq);
    let ones = vec![1.0f32; nq];
    let a = evaluate(mr, &tasks[0], &bf16, &ones).unwrap();
    let b = evaluate(mr, &tasks[0], &bf16, &ones).unwrap();
    assert_eq!(a.acc, b.acc);
    assert_eq!(a.ppl, b.ppl);
    // FP8 must change measured perplexity.
    let fp8 = MpConfig::uniform(nq, Format::Fp8E4m3);
    let mut rng = Rng::new(9);
    let ps = draw_pscale(nq, 0.02, &mut rng);
    let c = evaluate(mr, &tasks[0], &fp8, &ps).unwrap();
    assert!((c.ppl - a.ppl).abs() / a.ppl > 1e-4, "fp8 left ppl unchanged");
    // Scores are sane.
    for r in [&a, &c] {
        assert!(r.acc >= 0.0 && r.acc <= 1.0);
        assert!(r.ppl.is_finite() && r.ppl > 0.0);
    }
}

fn check_tau_zero(planner: &Planner) {
    let family = planner.family(Objective::EmpiricalTime);
    let out =
        optimize(&family.groups, planner.calibration(), 0.0, &ExecPool::sequential()).unwrap();
    assert_eq!(out.config.n_quantized(), 0);
}

fn check_wall_clock(info: &ModelInfo, mr: &ModelRuntime) {
    let calib = info.load_calib(&root()).unwrap();
    let tokens: Vec<i32> = calib[..info.eval_b].concat();
    let src = ampq::timing::WallTtft { mr, tokens, reps: 2 };
    use ampq::timing::TtftSource;
    let t = src.measure(&MpConfig::all_bf16(info.n_qlayers), 0).unwrap();
    assert!(t > 100.0, "wall-clock TTFT {t} us implausibly small");
    assert!(t < 10.0e6, "wall-clock TTFT {t} us implausibly large");
}
