//! Bench for Figure 1: regenerate the attention-sub-graph gain table
//! (32 configs x {measured-group, per-layer-sum, theoretical}) and time the
//! measurement harness end to end.

use ampq::gaudisim::{HwModel, Simulator};
use ampq::graph::partition::partition;
use ampq::model::Manifest;
use ampq::numerics::PAPER_FORMATS;
use ampq::exec::ExecPool;
use ampq::timing::{measure_groups, measure_per_layer, SimTtft};
use ampq::util::bench::{bench, black_box};
use std::path::Path;

fn main() {
    let manifest = Manifest::load(Path::new("artifacts")).expect("make artifacts");
    for model in ["tiny-s", "tiny-m"] {
        let info = manifest.model(model).unwrap();
        let graph = info.load_graph(&manifest.root).unwrap();
        let part = partition(&graph).unwrap();
        let hw = HwModel { noise_std: 0.0, ..HwModel::default() };

        let pool = ExecPool::sequential();
        bench(&format!("fig1/{model}/measure_all_groups"), 1, 5, || {
            let sim = Simulator::new(&graph, hw.clone());
            let src = SimTtft { sim, seed: 0, reps: 5 };
            black_box(measure_groups(&src, &part, &PAPER_FORMATS, &pool).unwrap());
        });
        bench(&format!("fig1/{model}/measure_per_layer"), 1, 5, || {
            let sim = Simulator::new(&graph, hw.clone());
            let src = SimTtft { sim, seed: 0, reps: 5 };
            black_box(measure_per_layer(&src, &PAPER_FORMATS, &pool).unwrap());
        });

        // Correctness shape check mirrored from the paper: per-layer sums
        // must mispredict the attention group's measured gains.
        let sim = Simulator::new(&graph, hw.clone());
        let src = SimTtft { sim, seed: 0, reps: 1 };
        let tm = measure_groups(&src, &part, &PAPER_FORMATS, &pool).unwrap();
        let pl_gains = measure_per_layer(&src, &PAPER_FORMATS, &pool).unwrap();
        let gi = part.groups.iter().position(|g| g.len() == 5).unwrap();
        let g = &tm.groups[gi];
        let worst_gap = g
            .configs
            .iter()
            .zip(&g.gains)
            .map(|(fmts, &m)| {
                let s: f64 = g
                    .qidxs
                    .iter()
                    .zip(fmts)
                    .map(|(&q, &f)| pl_gains[q][if f == ampq::numerics::Format::Bf16 { 0 } else { 1 }])
                    .sum();
                (s - m).abs()
            })
            .fold(0.0f64, f64::max);
        let max_gain = g.gains.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "fig1/{model}: worst per-layer-sum error {:.1} us = {:.0}% of max group gain {:.1} us",
            worst_gap,
            100.0 * worst_gap / max_gain,
            max_gain
        );
        assert!(worst_gap / max_gain > 0.05, "expected the Fig-1 non-additivity gap");
    }
}
