//! Ablations of the design choices DESIGN.md calls out:
//!   * hardware model: engine parallelism + fusion -> how much of the Fig-1
//!     non-additivity they create (the phenomenon motivating per-group
//!     measurement);
//!   * solver choice across tau (exact vs greedy gap on REAL calibrated
//!     instances, not synthetic ones);
//!   * partition granularity: per-group IP vs a per-layer (additivity-
//!     assuming) IP — the paper's central claim in optimization form.

use ampq::gaudisim::{HwModel, MpConfig, Simulator};
use ampq::metrics::{GroupChoices, Objective};
use ampq::numerics::{Format, PAPER_FORMATS};
use ampq::plan::Engine;
use ampq::solver::{branch_bound, greedy, Mckp};
use ampq::exec::ExecPool;
use ampq::timing::{measure_groups, measure_per_layer, SimTtft};

fn fig1_gap(graph: &ampq::graph::Graph, part: &ampq::graph::partition::Partition, hw: HwModel) -> f64 {
    let sim = Simulator::new(graph, hw.clone());
    let src = SimTtft { sim, seed: 0, reps: 1 };
    let pool = ExecPool::sequential();
    let tm = measure_groups(&src, part, &PAPER_FORMATS, &pool).unwrap();
    let pl = measure_per_layer(&src, &PAPER_FORMATS, &pool).unwrap();
    let gi = part.groups.iter().position(|g| g.len() == 5).unwrap();
    let g = &tm.groups[gi];
    let max_gain = g.gains.iter().cloned().fold(0.0f64, f64::max).max(1e-9);
    let mean_gap: f64 = g
        .configs
        .iter()
        .zip(&g.gains)
        .map(|(fmts, &m)| {
            let s: f64 = g
                .qidxs
                .iter()
                .zip(fmts)
                .map(|(&q, &f)| pl[q][if f == Format::Bf16 { 0 } else { 1 }])
                .sum();
            (s - m).abs()
        })
        .sum::<f64>()
        / g.gains.len() as f64;
    mean_gap / max_gain
}

fn main() {
    let base = HwModel { noise_std: 0.0, ..HwModel::default() };
    let mut quiet = ampq::backend::DeviceProfile::gaudi2();
    quiet.noise_std = 0.0;
    let mut engine = Engine::new()
        .with_artifacts_root("artifacts")
        .with_device(quiet);
    let part_art = engine.partitioned("tiny-s").expect("make artifacts");
    let graph = engine.graph("tiny-s").unwrap();
    let part = &part_art.partition;

    println!("== ablation: hardware-model features -> Fig-1 non-additivity gap ==");
    for (tag, hw) in [
        ("1 MME, no fusion", HwModel { n_mme: 1, enable_fusion: false, ..base.clone() }),
        ("1 MME, fusion", HwModel { n_mme: 1, ..base.clone() }),
        ("2 MME, no fusion", HwModel { n_mme: 2, enable_fusion: false, ..base.clone() }),
        ("2 MME, fusion (default)", base.clone()),
        ("4 MME, fusion", HwModel { n_mme: 4, ..base.clone() }),
    ] {
        println!("  {tag:<26} mean |sum-per-layer − measured| = {:.1}% of max group gain",
                 100.0 * fig1_gap(&graph, part, hw));
    }

    println!("\n== ablation: solver choice on the real calibrated IP ==");
    let planner = engine.planner("tiny-s").unwrap();
    let calibration = planner.calibration();
    let family = planner.family(Objective::EmpiricalTime);
    for tau in [0.001, 0.002, 0.004, 0.007] {
        let budget = calibration.budget(tau);
        let gains: Vec<Vec<f64>> = family.groups.iter().map(|g| g.gains.clone()).collect();
        let costs: Vec<Vec<f64>> = family
            .groups
            .iter()
            .map(|g| g.configs.iter().map(|c| calibration.group_mse(&g.qidxs, c)).collect())
            .collect();
        let p = Mckp::new(gains, costs, budget).unwrap();
        let e = branch_bound::solve(&p);
        let gr = greedy::solve(&p);
        println!(
            "  tau={tau:<6} exact gain {:>8.2} us | greedy {:>8.2} us ({:.2}% gap)",
            e.gain,
            gr.gain,
            100.0 * (1.0 - gr.gain / e.gain.max(1e-9))
        );
    }

    println!("\n== ablation: per-group (paper) vs per-layer-additivity IP ==");
    // Build a WRONG objective that assumes per-layer additivity, optimize
    // with it, then re-score the chosen config with the true simulator.
    let nq = planner.n_qlayers();
    let sim = Simulator::new(&graph, base.clone());
    let src = SimTtft { sim, seed: 1, reps: 5 };
    let per_layer = measure_per_layer(&src, &PAPER_FORMATS, &ExecPool::sequential()).unwrap();
    let naive_groups: Vec<GroupChoices> = (0..nq)
        .map(|l| GroupChoices {
            qidxs: vec![l],
            configs: vec![vec![Format::Bf16], vec![Format::Fp8E4m3]],
            gains: vec![0.0, per_layer[l][1]],
        })
        .collect();
    let sim2 = Simulator::new(&graph, base.clone());
    let base_ttft = sim2.makespan(&MpConfig::all_bf16(nq));
    for tau in [0.002, 0.004, 0.007] {
        let pool = ExecPool::sequential();
        let paper = ampq::coordinator::optimize(&family.groups, calibration, tau, &pool).unwrap();
        let naive = ampq::coordinator::optimize(&naive_groups, calibration, tau, &pool).unwrap();
        let t_paper = sim2.makespan(&paper.config);
        let t_naive = sim2.makespan(&naive.config);
        println!(
            "  tau={tau:<6} true TTFT: per-group IP {:>7.1} us | per-layer IP {:>7.1} us | baseline {:>7.1} us",
            t_paper, t_naive, base_ttft
        );
        assert!(t_paper <= t_naive + 1.0, "per-group IP must not lose to the naive IP");
    }
    println!("(per-group measurement finds configs at least as fast — and its gain\n predictions are trustworthy, which the per-layer model's are not; cf. Fig 1)");
}
