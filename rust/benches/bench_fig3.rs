//! Bench for Figure 3 (§3.2 validation): time the loss-MSE predictor vs a
//! measured loss-MSE pass, and regenerate the additivity check numbers.

use ampq::gaudisim::MpConfig;
use ampq::numerics::Format;
use ampq::plan::Engine;
use ampq::sensitivity::validate::measured_loss_mse;
use ampq::util::bench::{bench, black_box};
use ampq::util::Rng;

fn main() {
    let mut engine = Engine::new().with_artifacts_root("artifacts");
    let planner = engine.planner("tiny-s").expect("make artifacts");
    let info = engine.info("tiny-s").unwrap();
    let calib_tokens = info.load_calib(engine.artifacts_root().unwrap()).unwrap();
    let calibration = planner.calibration().clone();
    let nq = planner.n_qlayers();
    let fp8 = MpConfig::uniform(nq, Format::Fp8E4m3);

    // The predictor is the hot path of the IP inner loop: must be ~ns.
    bench("fig3/predict_loss_mse (eq. 6)", 100, 10_000, || {
        black_box(calibration.loss_mse(&fp8));
    });

    let mr = engine.runtime("tiny-s").expect("PJRT runtime");
    bench("fig3/measured_loss_mse (1 draw, 32 samples)", 0, 3, || {
        let mut rng = Rng::new(1);
        black_box(measured_loss_mse(mr, &calib_tokens, &fp8, 1, 0.02, &mut rng).unwrap());
    });

    // Shape check: prediction within an order of magnitude of measurement
    // and both monotone from BF16 -> FP8 (paper Fig. 3a).
    let mut rng = Rng::new(2);
    for fmt in [Format::Bf16, Format::Fp8E4m3] {
        let cfg = MpConfig::uniform(nq, fmt);
        let pred = calibration.loss_mse(&cfg);
        let meas = measured_loss_mse(mr, &calib_tokens, &cfg, 2, 0.02, &mut rng).unwrap();
        println!(
            "fig3/{}: predicted {pred:.3e} measured {meas:.3e} ratio {:.2}",
            fmt.name(),
            pred / meas
        );
        assert!(pred / meas > 0.05 && pred / meas < 20.0, "{fmt:?} prediction off");
    }
}
