//! Bench for Figure 3 (§3.2 validation): time the loss-MSE predictor vs a
//! measured loss-MSE pass, and regenerate the additivity check numbers.

use ampq::coordinator::Pipeline;
use ampq::gaudisim::{HwModel, MpConfig};
use ampq::numerics::{Format, PAPER_FORMATS};
use ampq::runtime::FwdMode;
use ampq::sensitivity::validate::measured_loss_mse;
use ampq::util::bench::{bench, black_box};
use ampq::util::Rng;
use ampq::model::Manifest;
use std::path::Path;

fn main() {
    let manifest = Manifest::load(Path::new("artifacts")).expect("make artifacts");
    let pl = Pipeline::new(&manifest, "tiny-s", FwdMode::Ref, HwModel::default(),
                           PAPER_FORMATS.to_vec())
        .unwrap();
    let calib = pl.info.load_calib(&manifest.root).unwrap();
    let nq = pl.info.n_qlayers;
    let fp8 = MpConfig::uniform(nq, Format::Fp8E4m3);

    // The predictor is the hot path of the IP inner loop: must be ~ns.
    bench("fig3/predict_loss_mse (eq. 6)", 100, 10_000, || {
        black_box(pl.calibration.loss_mse(&fp8));
    });

    bench("fig3/measured_loss_mse (1 draw, 32 samples)", 0, 3, || {
        let mut rng = Rng::new(1);
        black_box(measured_loss_mse(&pl.mr, &calib, &fp8, 1, 0.02, &mut rng).unwrap());
    });

    // Shape check: prediction within an order of magnitude of measurement
    // and both monotone from BF16 -> FP8 (paper Fig. 3a).
    let mut rng = Rng::new(2);
    for fmt in [Format::Bf16, Format::Fp8E4m3] {
        let cfg = MpConfig::uniform(nq, fmt);
        let pred = pl.calibration.loss_mse(&cfg);
        let meas = measured_loss_mse(&pl.mr, &calib, &cfg, 2, 0.02, &mut rng).unwrap();
        println!(
            "fig3/{}: predicted {pred:.3e} measured {meas:.3e} ratio {:.2}",
            fmt.name(),
            pred / meas
        );
        assert!(pred / meas > 0.05 && pred / meas < 20.0, "{fmt:?} prediction off");
    }
}
