//! PJRT runtime benchmarks: compile time, forward latency (Pallas-kernel vs
//! pure-jnp artifact), sensitivity pass — the L1/L2 execution costs as seen
//! from the rust hot path.

use ampq::gaudisim::MpConfig;
use ampq::model::Manifest;
use ampq::numerics::Format;
use ampq::runtime::{FwdMode, ModelRuntime, Runtime};
use ampq::util::bench::{bench, black_box};
use std::path::Path;

fn main() {
    let manifest = Manifest::load(Path::new("artifacts")).expect("make artifacts");
    let rt = Runtime::new().unwrap();
    let info = manifest.model("tiny-s").unwrap().clone();
    let calib = info.load_calib(&manifest.root).unwrap();
    let tokens: Vec<i32> = calib[..info.eval_b].concat();
    let nq = info.n_qlayers;
    let fp8 = MpConfig::uniform(nq, Format::Fp8E4m3);
    let ones = vec![1.0f32; nq];

    let t0 = std::time::Instant::now();
    let mr_pallas = ModelRuntime::load(&rt, &manifest.root, &info, FwdMode::Pallas).unwrap();
    println!("runtime/compile fwd_quant (pallas): {:.2}s", t0.elapsed().as_secs_f64());
    let t0 = std::time::Instant::now();
    let mr_ref = ModelRuntime::load(&rt, &manifest.root, &info, FwdMode::Ref).unwrap();
    println!("runtime/compile fwd_ref: {:.2}s", t0.elapsed().as_secs_f64());

    bench("runtime/fwd pallas (B=8, fp8)", 2, 20, || {
        black_box(mr_pallas.fwd(&tokens, &fp8, &ones).unwrap());
    });
    bench("runtime/fwd ref (B=8, fp8)", 2, 20, || {
        black_box(mr_ref.fwd(&tokens, &fp8, &ones).unwrap());
    });
    bench("runtime/fwd ref (B=8, fp32 identity)", 2, 20, || {
        black_box(mr_ref.fwd_fp32(&tokens).unwrap());
    });
    bench("runtime/sensitivity (B=1 fwd+bwd)", 2, 20, || {
        black_box(mr_ref.sensitivity(&calib[0]).unwrap());
    });

    // Numerical agreement between the two artifacts at identity precision.
    let a = mr_pallas.fwd_fp32(&tokens).unwrap();
    let b = mr_ref.fwd_fp32(&tokens).unwrap();
    let max_diff = a
        .logits
        .iter()
        .zip(&b.logits)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("runtime/pallas-vs-ref max |logit diff| at fp32: {max_diff:.2e}");
    assert!(max_diff < 1e-3);
}
