//! Bench for Figures 4/5/7 + Table 1: a reduced accuracy-vs-TTFT sweep
//! (IP-ET family, 3 strategies, 2 seeds) with the end-to-end timing of the
//! evaluation hot loop — the dominant cost of regenerating the paper.

use ampq::coordinator::Strategy;
use ampq::evalharness::{load_all_tasks, CachedEvaluator};
use ampq::figures::sweep::{aggregate, run_sweep, SweepInputs};
use ampq::gaudisim::MpConfig;
use ampq::metrics::Objective;
use ampq::plan::Engine;
use ampq::util::bench::bench;

fn main() {
    let mut engine = Engine::new().with_artifacts_root("artifacts");
    let planner = engine.planner("tiny-s").expect("make artifacts");
    let info = engine.info("tiny-s").unwrap();
    let graph = engine.graph("tiny-s").unwrap();
    let root = engine.artifacts_root().unwrap().to_path_buf();
    let tasks = load_all_tasks(&root, &info).unwrap();
    let device = engine.device().clone();
    let mr = engine.runtime("tiny-s").expect("PJRT runtime");

    // Single-task single-config eval: the innermost unit.
    let nq = info.n_qlayers;
    let cfg = MpConfig::all_bf16(nq);
    let ones = vec![1.0f32; nq];
    bench("table1/eval_one_task (hella, 256 rows)", 1, 3, || {
        ampq::evalharness::evaluate(mr, &tasks[0], &cfg, &ones).unwrap();
    });

    let t0 = std::time::Instant::now();
    let mut eval = CachedEvaluator::new(mr, &tasks);
    let inputs = SweepInputs {
        planner: &planner,
        qlayers: &info.qlayers,
        graph: &graph,
        device,
        tasks: &tasks,
    };
    let sweep = run_sweep(
        &inputs,
        Objective::EmpiricalTime,
        &[0.0, 0.004, 0.007],
        2,
        0.02,
        &[Strategy::Ip, Strategy::Random, Strategy::Prefix],
        &mut eval,
    )
    .unwrap();
    println!(
        "table1/reduced_sweep: {} points, {} unique configs, {:.1}s total",
        sweep.points.len(),
        eval.cache_len(),
        t0.elapsed().as_secs_f64()
    );

    // Paper-shape check: IP-ET's accuracy at the tightest nonzero tau should
    // not be materially worse than the baselines', and its TTFT not slower.
    let ip = aggregate(&sweep, Strategy::Ip);
    let rnd = aggregate(&sweep, Strategy::Random);
    let last = ip.len() - 1;
    println!(
        "table1 shape: tau={:.3} IP {:+.3}% @ {:.0}us | Random {:+.3}% @ {:.0}us",
        ip[last].tau, ip[last].acc_diff_mean, ip[last].ttft_us,
        rnd[last].acc_diff_mean, rnd[last].ttft_us
    );
}
