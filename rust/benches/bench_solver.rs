//! MCKP solver micro-benchmarks (L3 hot path): exact branch & bound vs DP
//! vs greedy vs LP relaxation, on paper-scale and stress-scale instances.
//!
//! Emits a machine-readable summary to BENCH_solver.json (override with
//! BENCH_OUT=path) so CI records perf-trajectory data points.

use ampq::solver::{branch_bound, dp, greedy, lp_relax, Mckp};
use ampq::util::bench::{bench, black_box, write_summary};
use ampq::util::{Json, Rng};
use std::path::PathBuf;

fn paper_scale_instance(seed: u64) -> Mckp {
    // Llama-like: per block {32-config attention, 2, 4, 2} + lm_head,
    // 8 blocks -> 33 groups.
    let mut rng = Rng::new(seed);
    let mut gains = Vec::new();
    let mut costs = Vec::new();
    for _ in 0..8 {
        for &n in &[32usize, 2, 4, 2] {
            gains.push((0..n).map(|_| rng.f64() * 100.0).collect::<Vec<f64>>());
            costs.push((0..n).map(|_| rng.f64() * 1.0e-4).collect::<Vec<f64>>());
        }
    }
    gains.push(vec![0.0, 50.0]);
    costs.push(vec![1.0e-6, 1.0e-4]);
    let total: f64 = costs.iter().map(|c| c.iter().cloned().fold(0.0, f64::max)).sum();
    Mckp::new(gains, costs, total * 0.4).unwrap()
}

fn main() {
    let p = paper_scale_instance(7);
    println!(
        "instance: {} groups, {} total choices",
        p.n_groups(),
        p.gains.iter().map(|g| g.len()).sum::<usize>()
    );

    let results = vec![
        bench("solver/branch_bound (exact)", 3, 50, || {
            black_box(branch_bound::solve(&p));
        }),
        bench("solver/dp (8192 buckets)", 3, 50, || {
            black_box(dp::solve(&p));
        }),
        bench("solver/greedy", 3, 200, || {
            black_box(greedy::solve(&p));
        }),
        bench("solver/lp_relax", 3, 200, || {
            black_box(lp_relax::solve(&p));
        }),
    ];

    // Solution-quality ablation (DESIGN.md ablations).
    let mut quality: Vec<(String, Json)> = Vec::new();
    let exact = branch_bound::solve(&p);
    for (name, sol) in [("dp", dp::solve(&p)), ("greedy", greedy::solve(&p))] {
        println!(
            "solver/{name}: gain {:.3} = {:.4} of exact ({:.3}), budget used {:.1}%",
            sol.gain,
            sol.gain / exact.gain,
            exact.gain,
            100.0 * sol.cost / p.budget()
        );
        assert!(sol.gain <= exact.gain + 1e-9);
        assert!(sol.gain >= 0.90 * exact.gain, "{name} quality regression");
        quality.push((format!("{name}_of_exact"), Json::Num(sol.gain / exact.gain)));
    }
    let lp = lp_relax::solve(&p);
    assert!(lp.bound >= exact.gain - 1e-9);
    println!(
        "solver/lp bound {:.3} >= exact {:.3} (gap {:.3}%)",
        lp.bound,
        exact.gain,
        100.0 * (lp.bound / exact.gain - 1.0)
    );
    quality.push(("exact_gain".into(), Json::Num(exact.gain)));
    quality.push(("lp_bound_gap".into(), Json::Num(lp.bound / exact.gain - 1.0)));
    quality.push(("n_groups".into(), Json::Num(p.n_groups() as f64)));

    // Machine-readable summary: the perf trajectory's data point.
    let out = PathBuf::from(
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_solver.json".to_string()),
    );
    match write_summary(&out, "solver", &results, quality) {
        Ok(()) => println!("bench summary written to {}", out.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", out.display()),
    }
}
