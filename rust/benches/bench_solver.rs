//! MCKP solver micro-benchmarks (L3 hot path): exact branch & bound vs DP
//! vs greedy vs LP relaxation on paper-scale instances, plus the parallel
//! execution layer's scaling story — branch & bound and frontier sweeps at
//! 1, 2, and max threads (bit-identical outputs, different wall clocks).
//!
//! Emits a machine-readable summary to BENCH_solver.json (override with
//! BENCH_OUT=path) so CI records perf-trajectory data points, including
//! one entry per thread count for the parallel cases.

use ampq::backend::DeviceProfile;
use ampq::coordinator::Strategy;
use ampq::dist::{Coordinator, DistConfig};
use ampq::exec::{ExecCfg, ExecPool};
use ampq::metrics::Objective;
use ampq::numerics::PAPER_FORMATS;
use ampq::plan::demo::demo_model;
use ampq::plan::engine::{DEFAULT_MEASURE_REPS, DEFAULT_MEASURE_SEED};
use ampq::plan::stage::{MeasureStage, PartitionStage, Stage};
use ampq::plan::Engine;
use ampq::solver::{branch_bound, dp, greedy, lp_relax, Mckp};
use ampq::util::bench::{bench, black_box, write_summary};
use ampq::util::{Json, Rng};
use std::path::PathBuf;

fn paper_scale_instance(seed: u64) -> Mckp {
    // Llama-like: per block {32-config attention, 2, 4, 2} + lm_head,
    // 8 blocks -> 33 groups.
    let mut rng = Rng::new(seed);
    let mut gains = Vec::new();
    let mut costs = Vec::new();
    for _ in 0..8 {
        for &n in &[32usize, 2, 4, 2] {
            gains.push((0..n).map(|_| rng.f64() * 100.0).collect::<Vec<f64>>());
            costs.push((0..n).map(|_| rng.f64() * 1.0e-4).collect::<Vec<f64>>());
        }
    }
    gains.push(vec![0.0, 50.0]);
    costs.push(vec![1.0e-6, 1.0e-4]);
    let total: f64 = costs.iter().map(|c| c.iter().cloned().fold(0.0, f64::max)).sum();
    Mckp::new(gains, costs, total * 0.4).unwrap()
}

/// Thread counts to sweep: 1, 2, and the machine's max (deduped).
fn thread_counts() -> Vec<usize> {
    let max = ExecCfg::from_env().threads;
    let mut ts = vec![1usize, 2, max];
    ts.sort_unstable();
    ts.dedup();
    ts
}

fn main() {
    // Tracing off for the whole run: span/counter bookkeeping inside the
    // solver hot loops would tax exactly the sections being timed, and a
    // stray AMPQ_TRACE in the CI environment must not skew the committed
    // baseline.
    ampq::obs::set_enabled(false);

    let p = paper_scale_instance(7);
    println!(
        "instance: {} groups, {} total choices",
        p.n_groups(),
        p.gains.iter().map(|g| g.len()).sum::<usize>()
    );

    let mut results = vec![
        bench("solver/branch_bound (exact)", 3, 50, || {
            black_box(branch_bound::solve(&p));
        }),
        bench("solver/dp (8192 buckets)", 3, 50, || {
            black_box(dp::solve(&p));
        }),
        bench("solver/greedy", 3, 200, || {
            black_box(greedy::solve(&p));
        }),
        bench("solver/lp_relax", 3, 200, || {
            black_box(lp_relax::solve(&p));
        }),
    ];

    // Parallel scaling: the SAME solve at 1 / 2 / max threads.  Outputs
    // are bit-identical (asserted); only the wall clock may move.
    let mut quality: Vec<(String, Json)> = Vec::new();
    let reference = branch_bound::solve_with(&p, &ExecPool::sequential());
    let mut per_thread_mean: Vec<(usize, f64)> = Vec::new();
    for &t in &thread_counts() {
        let pool = ExecPool::new(ExecCfg::new(t));
        assert_eq!(
            branch_bound::solve_with(&p, &pool),
            reference,
            "threads={t} must be bit-identical"
        );
        let r = bench(&format!("solver/branch_bound/threads={t}"), 2, 30, || {
            black_box(branch_bound::solve_with(&p, &pool));
        });
        per_thread_mean.push((t, r.mean_us));
        results.push(r);
    }
    if let (Some((_, t1)), Some((tmax, tn))) = (per_thread_mean.first(), per_thread_mean.last())
    {
        let speedup = t1 / tn.max(1e-9);
        println!("solver/branch_bound: {speedup:.2}x speedup at {tmax} threads vs 1");
        quality.push(("bb_speedup_max_threads".into(), Json::Num(speedup)));
        quality.push(("bb_max_threads".into(), Json::Num(*tmax as f64)));
    }

    // Frontier old-vs-new: the bisection sweep (one IP solve per probe,
    // the pre-parametric path, kept as the oracle) against the one-pass
    // parametric chain DP that replaced it.  Same curve — every knot the
    // bisection localized must appear on the parametric curve — but the DP
    // does ~one sweep's work instead of one branch & bound solve per knot.
    {
        let mut engine = demo_engine(1);
        let planner = engine.planner("demo").unwrap();
        let f_new = planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
        let f_old = planner
            .frontier_via_bisection(Objective::EmpiricalTime, Strategy::Ip)
            .unwrap();
        for (i, old) in f_old.points.iter().enumerate() {
            assert!(
                f_new.points.iter().any(|p| (p.gain - old.gain).abs() <= 1e-9
                    && (p.predicted_mse - old.predicted_mse).abs() <= 1e-12),
                "bisection knot {i} (gain {}) missing from the parametric curve",
                old.gain
            );
        }
        let r_new = bench("frontier/demo/parametric (one-pass)", 1, 8, || {
            black_box(planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap());
        });
        let r_old = bench("frontier/demo/bisection (per-tau solves)", 1, 3, || {
            black_box(
                planner
                    .frontier_via_bisection(Objective::EmpiricalTime, Strategy::Ip)
                    .unwrap(),
            );
        });
        let speedup = r_old.mean_us / r_new.mean_us.max(1e-9);
        println!(
            "frontier/demo: parametric one-pass {speedup:.1}x faster than bisection \
             ({} knots vs {} localized)",
            f_new.len(),
            f_old.len()
        );
        quality.push(("frontier_parametric_speedup_vs_bisection".into(), Json::Num(speedup)));
        quality.push(("frontier_knots_parametric".into(), Json::Num(f_new.len() as f64)));
        quality.push(("frontier_knots_bisection".into(), Json::Num(f_old.len() as f64)));
        results.push(r_old);
        results.push(r_new);
    }

    // Frontier thread scaling: the parametric sweep's state merge fans out
    // across the pool (bit-identical curves, different wall clocks).
    let mut frontier_mean: Vec<(usize, f64)> = Vec::new();
    for &t in &thread_counts() {
        let mut engine = demo_engine(t);
        let planner = engine.planner("demo").unwrap();
        let r = bench(&format!("frontier/demo/threads={t}"), 1, 8, || {
            black_box(planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap());
        });
        frontier_mean.push((t, r.mean_us));
        results.push(r);
    }
    // Cross-thread equality of the swept frontier (the determinism
    // contract, asserted on the bench workload too).
    let f1 = demo_engine(1)
        .planner("demo")
        .unwrap()
        .frontier(Objective::EmpiricalTime, Strategy::Ip)
        .unwrap();
    let fmax = demo_engine(ExecCfg::from_env().threads)
        .planner("demo")
        .unwrap()
        .frontier(Objective::EmpiricalTime, Strategy::Ip)
        .unwrap();
    assert_eq!(f1, fmax, "frontier must be bit-identical across thread counts");
    if let (Some((_, t1)), Some((tmax, tn))) = (frontier_mean.first(), frontier_mean.last()) {
        let speedup = t1 / tn.max(1e-9);
        println!("frontier/demo: {speedup:.2}x speedup at {tmax} threads vs 1");
        quality.push(("frontier_speedup_max_threads".into(), Json::Num(speedup)));
    }

    // Steady-state frontier serving at max threads: after the first solve
    // commits the arena, every re-solve reuses the committed level columns
    // (`Planner::frontier` runs through the persistent FrontierDp), so this
    // is the daemon's hot refresh path.  Also records the arena's peak live
    // DP-state count and resident bytes — the SoA layout's footprint.
    {
        let tmax = ExecCfg::from_env().threads;
        let mut engine = demo_engine(tmax);
        let planner = engine.planner("demo").unwrap();
        planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap();
        let r = bench(&format!("frontier/demo/steady-state/threads={tmax}"), 2, 16, || {
            black_box(planner.frontier(Objective::EmpiricalTime, Strategy::Ip).unwrap());
        });
        let throughput = 1.0e6 / r.mean_us.max(1e-9);
        let stats = planner.frontier_dp_stats(Objective::EmpiricalTime);
        println!(
            "frontier/demo: steady-state {throughput:.0} curves/s ({} peak live DP states, \
             {} arena bytes)",
            stats.peak_live_states, stats.arena_bytes
        );
        quality.push(("frontier_throughput_curves_per_sec".into(), Json::Num(throughput)));
        quality
            .push(("frontier_peak_dp_states".into(), Json::Num(stats.peak_live_states as f64)));
        quality.push(("frontier_arena_bytes".into(), Json::Num(stats.arena_bytes as f64)));
        results.push(r);
    }

    // Distributed measurement throughput: the fleet-sharded Measured
    // stage (2 `ampq worker` subprocesses, stdio pipes) against the
    // in-process sequential stage — same bytes (asserted), the ratio
    // records what process fan-out costs/buys on this workload.
    {
        let (graph, qlayers, _) = demo_model(4, 11);
        let device = DeviceProfile::gaudi2();
        let menu = device.restrict_menu(&PAPER_FORMATS);
        let seq = ExecPool::sequential();
        let partitioned = PartitionStage {
            model: "demo",
            graph: &graph,
            qlayers: &qlayers,
            menu: &menu,
        }
        .run(&seq)
        .unwrap();
        let ms = MeasureStage {
            model: "demo",
            graph: &graph,
            partitioned: &partitioned,
            device: &device,
            seed: DEFAULT_MEASURE_SEED,
            reps: DEFAULT_MEASURE_REPS,
        };
        let reference = ms.run(&seq).unwrap();
        let r_local = bench("measure/demo/in-process", 1, 5, || {
            black_box(ms.run(&seq).unwrap());
        });
        let dist_cfg = DistConfig {
            workers: 2,
            worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_ampq"))),
            ..DistConfig::default()
        };
        match Coordinator::new(dist_cfg) {
            Ok(mut coord) => {
                assert_eq!(
                    coord.measure_stage(&ms).unwrap(),
                    reference,
                    "distributed Measured must be bit-identical"
                );
                let r_dist = bench("measure/demo/dist/workers=2", 1, 5, || {
                    black_box(coord.measure_stage(&ms).unwrap());
                });
                let ratio = r_local.mean_us / r_dist.mean_us.max(1e-9);
                println!(
                    "measure/demo: distributed (2 workers) runs at {ratio:.2}x the \
                     in-process rate"
                );
                quality.push((
                    "measure_dist_vs_in_process_speedup".into(),
                    Json::Num(ratio),
                ));
                quality.push(("measure_dist_workers".into(), Json::Num(2.0)));
                results.push(r_dist);
                coord.shutdown();
            }
            Err(e) => eprintln!("warning: skipping distributed measure bench ({e:#})"),
        }
        results.push(r_local);
    }

    // Solution-quality ablation (DESIGN.md ablations).
    let exact = branch_bound::solve(&p);
    for (name, sol) in [("dp", dp::solve(&p)), ("greedy", greedy::solve(&p))] {
        println!(
            "solver/{name}: gain {:.3} = {:.4} of exact ({:.3}), budget used {:.1}%",
            sol.gain,
            sol.gain / exact.gain,
            exact.gain,
            100.0 * sol.cost / p.budget()
        );
        assert!(sol.gain <= exact.gain + 1e-9);
        assert!(sol.gain >= 0.90 * exact.gain, "{name} quality regression");
        quality.push((format!("{name}_of_exact"), Json::Num(sol.gain / exact.gain)));
    }
    let lp = lp_relax::solve(&p);
    assert!(lp.bound >= exact.gain - 1e-9);
    println!(
        "solver/lp bound {:.3} >= exact {:.3} (gap {:.3}%)",
        lp.bound,
        exact.gain,
        100.0 * (lp.bound / exact.gain - 1.0)
    );
    quality.push(("exact_gain".into(), Json::Num(exact.gain)));
    quality.push(("lp_bound_gap".into(), Json::Num(lp.bound / exact.gain - 1.0)));
    quality.push(("n_groups".into(), Json::Num(p.n_groups() as f64)));

    // Machine-readable summary: the perf trajectory's data point.
    let out = PathBuf::from(
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_solver.json".to_string()),
    );
    // Fail LOUDLY: a missing summary silently drops the perf-trajectory
    // data point CI exists to record.
    match write_summary(&out, "solver", &results, quality) {
        Ok(()) => println!("bench summary written to {}", out.display()),
        Err(e) => {
            eprintln!("error: could not write {}: {e}", out.display());
            std::process::exit(1);
        }
    }
}

/// A 4-block demo engine at the given thread budget (cache disabled so
/// every staging is a real measurement pass).
fn demo_engine(threads: usize) -> Engine {
    let (graph, qlayers, calibration) = demo_model(4, 11);
    let mut engine = Engine::new().with_threads(threads);
    engine.register_synthetic("demo", graph, qlayers, calibration);
    engine
}
