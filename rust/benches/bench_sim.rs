//! Gaudi-2-like simulator micro-benchmarks: partition + makespan scheduling
//! (the inner loop of every time-gain measurement).

use ampq::gaudisim::{HwModel, MpConfig, Simulator};
use ampq::graph::partition::partition;
use ampq::model::Manifest;
use ampq::numerics::Format;
use ampq::util::bench::{bench, black_box};
use std::path::Path;

fn main() {
    let manifest = Manifest::load(Path::new("artifacts")).expect("make artifacts");
    for model in ["tiny-s", "tiny-m"] {
        let info = manifest.model(model).unwrap();
        let graph = info.load_graph(&manifest.root).unwrap();
        println!("{model}: {} nodes, {} edges", graph.nodes.len(), graph.edges.len());

        bench(&format!("sim/{model}/partition"), 10, 1000, || {
            black_box(partition(&graph).unwrap());
        });

        let hw = HwModel { noise_std: 0.0, ..HwModel::default() };
        let sim = Simulator::new(&graph, hw.clone());
        let cfg = MpConfig::uniform(graph.qlayers.len(), Format::Fp8E4m3);
        bench(&format!("sim/{model}/makespan (ready-list)"), 10, 1000, || {
            black_box(sim.makespan(&cfg));
        });
        bench(&format!("sim/{model}/makespan_scan (reference)"), 10, 1000, || {
            black_box(sim.makespan_scan(&cfg));
        });
        assert_eq!(sim.makespan(&cfg), sim.makespan_scan(&cfg));
        bench(&format!("sim/{model}/simulator_new"), 10, 1000, || {
            black_box(Simulator::new(&graph, hw.clone()));
        });

        // A full Algorithm-1 measurement pass (dominates `ampq measure`).
        let part = partition(&graph).unwrap();
        let n_meas = part.n_measurements(2).unwrap() + 1;
        let pool = ampq::exec::ExecPool::sequential();
        let r = bench(&format!("sim/{model}/full_measurement_pass"), 1, 10, || {
            let sim = Simulator::new(&graph, hw.clone());
            let src = ampq::timing::SimTtft { sim, seed: 1, reps: 5 };
            let fmts = &ampq::numerics::PAPER_FORMATS;
            black_box(ampq::timing::measure_groups(&src, &part, fmts, &pool).unwrap());
        });
        println!(
            "sim/{model}: {} TTFT measurements x 5 reps -> {:.2} us per makespan call",
            n_meas,
            r.mean_us / (n_meas * 5) as f64
        );
    }
}
