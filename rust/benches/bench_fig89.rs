//! Bench for Figures 8 and 9: the IP-TT (MAC-time) and IP-M (memory)
//! planner queries across the tau grid, driven by cached stage artifacts.

use ampq::coordinator::paper_tau_grid;
use ampq::metrics::Objective;
use ampq::plan::{Engine, PlanRequest};
use ampq::util::bench::{bench, black_box};

fn main() {
    let mut engine = Engine::new().with_artifacts_root("artifacts");
    for model in ["tiny-s", "tiny-m"] {
        let planner = engine.planner(model).expect("make artifacts");

        for objective in [Objective::TheoreticalTime, Objective::Memory] {
            bench(&format!("fig89/{model}/{}/solve_tau_grid", objective.name()), 1, 10, || {
                for tau in paper_tau_grid() {
                    let req = PlanRequest::new(objective).with_loss_budget(tau);
                    black_box(planner.solve(&req).unwrap());
                }
            });

            // Shape check: gains monotone in tau; memory family never
            // touches BGEMM layers.
            let mut last = -1.0f64;
            for tau in paper_tau_grid() {
                let plan = planner
                    .solve(&PlanRequest::new(objective).with_loss_budget(tau))
                    .unwrap();
                assert!(plan.gain >= last - 1e-9);
                last = plan.gain;
                if objective == Objective::Memory {
                    for (l, q) in planner.partitioned().qlayers.iter().enumerate() {
                        if q.kind == ampq::model::LayerKind::Bgemm {
                            assert_eq!(plan.config.get(l), ampq::numerics::Format::Bf16);
                        }
                    }
                }
            }
            println!(
                "fig89/{model}/{}: monotone gains up to {:.3e}",
                objective.name(),
                last
            );
        }
    }
    let c = engine.counters();
    println!(
        "fig89: both models served by {} calibration + {} measurement passes",
        c.calibration_passes, c.measurement_passes
    );
}
