//! Bench for Figures 8 and 9: the IP-TT (MAC-time) and IP-M (memory)
//! objective builders and solves across the tau grid.

use ampq::coordinator::{optimize, paper_tau_grid, Pipeline};
use ampq::gaudisim::HwModel;
use ampq::metrics::Objective;
use ampq::model::Manifest;
use ampq::numerics::PAPER_FORMATS;
use ampq::runtime::FwdMode;
use ampq::util::bench::{bench, black_box};
use std::path::Path;

fn main() {
    let manifest = Manifest::load(Path::new("artifacts")).expect("make artifacts");
    for model in ["tiny-s", "tiny-m"] {
        let pl = Pipeline::new(&manifest, model, FwdMode::Ref, HwModel::default(),
                               PAPER_FORMATS.to_vec())
            .unwrap();
        let tm = pl.measure_time(0, 5).unwrap();

        for objective in [Objective::TheoreticalTime, Objective::Memory] {
            let family = pl.family(objective, &tm);
            bench(&format!("fig89/{model}/{}/build+solve_tau_grid", objective.name()), 1, 10, || {
                for tau in paper_tau_grid() {
                    black_box(optimize(&family.groups, &pl.calibration, tau).unwrap());
                }
            });

            // Shape check: gains monotone in tau; memory family never
            // touches BGEMM layers.
            let mut last = -1.0f64;
            for tau in paper_tau_grid() {
                let out = optimize(&family.groups, &pl.calibration, tau).unwrap();
                assert!(out.solution.gain >= last - 1e-9);
                last = out.solution.gain;
                if objective == Objective::Memory {
                    for (l, q) in pl.info.qlayers.iter().enumerate() {
                        if q.kind == ampq::model::LayerKind::Bgemm {
                            assert_eq!(out.config.get(l), ampq::numerics::Format::Bf16);
                        }
                    }
                }
            }
            println!(
                "fig89/{model}/{}: monotone gains up to {:.3e}",
                objective.name(),
                last
            );
        }
    }
}
