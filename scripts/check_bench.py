#!/usr/bin/env python3
"""Validate a BENCH_solver.json summary and (optionally) gate it against
the committed baseline.

Two modes:

  # schema only — is this file a well-formed solver bench summary?
  python3 scripts/check_bench.py --schema rust/BENCH_solver.json

  # schema + regression gate: fresh values must stay above
  # RATIO x committed on every gated key (CI's solver-bench job)
  python3 scripts/check_bench.py --baseline BENCH_solver.json \
      --fresh rust/BENCH_solver.json [--ratio 0.8]

Exit status: 0 = pass, 1 = schema violation or perf regression.
The gated-key list lives here, in one place, instead of being duplicated
between the workflow file and the docs.
"""

import argparse
import json
import sys

# Every solver bench summary must carry these.  `bench` identifies the
# suite; the two metric keys are the perf-trajectory series EXPERIMENTS.md
# tracks and the CI gate enforces.
REQUIRED_KEYS = {
    "bench": str,
    "frontier_parametric_speedup_vs_bisection": (int, float),
    "frontier_throughput_curves_per_sec": (int, float),
}

GATED_KEYS = [
    "frontier_parametric_speedup_vs_bisection",
    "frontier_throughput_curves_per_sec",
]


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except OSError as e:
        sys.exit(f"check_bench: cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        sys.exit(f"check_bench: {path} is not valid JSON: {e}")


def check_schema(doc, path):
    errors = []
    if not isinstance(doc, dict):
        sys.exit(f"check_bench: {path}: top level must be an object")
    for key, want in REQUIRED_KEYS.items():
        if key not in doc:
            errors.append(f"missing required key '{key}'")
        elif not isinstance(doc[key], want) or isinstance(doc[key], bool):
            errors.append(f"key '{key}' has type {type(doc[key]).__name__}, "
                          f"expected {want if isinstance(want, type) else 'number'}")
    if doc.get("bench") not in (None, "solver"):
        errors.append(f"key 'bench' is '{doc.get('bench')}', expected 'solver'")
    for key in GATED_KEYS:
        v = doc.get(key)
        if isinstance(v, (int, float)) and not isinstance(v, bool) and v <= 0:
            errors.append(f"gated key '{key}' must be positive, got {v}")
    if errors:
        for e in errors:
            print(f"check_bench: {path}: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"check_bench: {path}: schema OK "
          f"({', '.join(f'{k}={doc[k]:.2f}' for k in GATED_KEYS)})")


def gate(base, fresh, ratio):
    bad = []
    for key in GATED_KEYS:
        floor = ratio * base[key]
        print(f"{key}: fresh {fresh[key]:.2f} vs committed {base[key]:.2f} "
              f"(floor {floor:.2f})")
        if fresh[key] < floor:
            bad.append(key)
    if bad:
        sys.exit(f"check_bench: perf regression below floor: {', '.join(bad)}")
    print("check_bench: perf gate passed")


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--schema", metavar="FILE",
                    help="validate FILE's schema and exit")
    ap.add_argument("--baseline", metavar="FILE",
                    help="committed baseline summary for the regression gate")
    ap.add_argument("--fresh", metavar="FILE",
                    help="freshly measured summary to gate against --baseline")
    ap.add_argument("--ratio", type=float, default=0.8,
                    help="regression floor as a fraction of baseline (default 0.8)")
    args = ap.parse_args()

    if args.schema:
        check_schema(load(args.schema), args.schema)
        return
    if args.baseline and args.fresh:
        base, fresh = load(args.baseline), load(args.fresh)
        check_schema(base, args.baseline)
        check_schema(fresh, args.fresh)
        gate(base, fresh, args.ratio)
        return
    ap.error("need --schema FILE, or --baseline FILE --fresh FILE")


if __name__ == "__main__":
    main()
