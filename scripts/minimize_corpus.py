#!/usr/bin/env python3
"""Minimize a failing `tests/corpus/*.json` replay (ddmin-style).

Given a corpus file and an interestingness command, repeatedly deletes
groups and per-group options from a `mckp_oracle` instance while the
command still reports the failure, then writes the smallest reproducer.

  python3 scripts/minimize_corpus.py tests/corpus/foo.json \
      --check 'cargo test -q --test fuzz_corpus -- corpus_replays 2>/dev/null; test $? -ne 0' \
      --out tests/corpus/foo.min.json

The check command is run with `{}` replaced by the candidate file path
(appended if no `{}` is present).  A candidate is "interesting" — i.e.
still reproduces the failure — when the command exits NON-zero, matching
the natural shape of `cargo test` on a failing replay.

`tau_reject` files are single-scalar reproducers: there is nothing to
delete, so they are copied through unchanged.

Deterministic: candidates are tried in a fixed order, no randomness.
"""

import argparse
import json
import subprocess
import sys
import tempfile
import os


def run_check(cmd, path):
    """True iff the failure still reproduces on `path`."""
    full = cmd.replace("{}", path) if "{}" in cmd else f"{cmd} {path}"
    r = subprocess.run(full, shell=True,
                       stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    return r.returncode != 0


def interesting(doc, cmd, tmpdir):
    fd, path = tempfile.mkstemp(suffix=".json", dir=tmpdir)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1)
        return run_check(cmd, path)
    finally:
        os.unlink(path)


def drop_group(doc, g):
    out = dict(doc)
    out["gains"] = [r for i, r in enumerate(doc["gains"]) if i != g]
    out["costs"] = [r for i, r in enumerate(doc["costs"]) if i != g]
    return out


def drop_option(doc, g, k):
    out = dict(doc)
    out["gains"] = [list(r) for r in doc["gains"]]
    out["costs"] = [list(r) for r in doc["costs"]]
    del out["gains"][g][k]
    del out["costs"][g][k]
    return out


def minimize_mckp(doc, cmd, tmpdir):
    tried = 0
    # Phase 1: whole groups, highest index first so indices stay valid.
    changed = True
    while changed:
        changed = False
        for g in range(len(doc["gains"]) - 1, -1, -1):
            if len(doc["gains"]) == 1:
                break
            cand = drop_group(doc, g)
            tried += 1
            if interesting(cand, cmd, tmpdir):
                doc = cand
                changed = True
    # Phase 2: individual options (each group keeps at least one).
    changed = True
    while changed:
        changed = False
        for g in range(len(doc["gains"])):
            for k in range(len(doc["gains"][g]) - 1, -1, -1):
                if len(doc["gains"][g]) == 1:
                    break
                cand = drop_option(doc, g, k)
                tried += 1
                if interesting(cand, cmd, tmpdir):
                    doc = cand
                    changed = True
    return doc, tried


def size_of(doc):
    if doc.get("kind") != "mckp_oracle":
        return 1
    return sum(len(r) for r in doc["gains"])


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("input", help="failing corpus file (tests/corpus/*.json)")
    ap.add_argument("--check", required=True, metavar="CMD",
                    help="shell command; non-zero exit on `{}` = still failing")
    ap.add_argument("--out", metavar="FILE",
                    help="where to write the reproducer (default: INPUT.min.json)")
    args = ap.parse_args()

    with open(args.input) as f:
        doc = json.load(f)
    out_path = args.out or (args.input[:-5] if args.input.endswith(".json")
                            else args.input) + ".min.json"

    with tempfile.TemporaryDirectory(prefix="minimize-corpus-") as tmpdir:
        if not interesting(doc, args.check, tmpdir):
            sys.exit(f"minimize_corpus: {args.input} is not interesting under "
                     f"--check (command exited zero); nothing to minimize")

        kind = doc.get("kind")
        if kind == "mckp_oracle":
            before = size_of(doc)
            doc, tried = minimize_mckp(doc, args.check, tmpdir)
            after = size_of(doc)
            print(f"minimize_corpus: {args.input}: {before} -> {after} options "
                  f"({len(doc['gains'])} group(s), {tried} candidates tried)")
        elif kind == "tau_reject":
            print(f"minimize_corpus: {args.input}: tau_reject is already "
                  f"minimal (single scalar); copying through")
        else:
            sys.exit(f"minimize_corpus: unknown corpus kind '{kind}' "
                     f"(supported: mckp_oracle, tau_reject)")

    doc["note"] = (f"Minimized from {os.path.basename(args.input)} by "
                   f"scripts/minimize_corpus.py. " + str(doc.get("note", "")))
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"minimize_corpus: wrote {out_path}")


if __name__ == "__main__":
    main()
