#!/usr/bin/env python3
"""Validate a Chrome trace-event (Perfetto) JSON file emitted by
`ampq trace --out FILE` or the `--trace FILE` flag.

Checks the schema the exporters promise (src/obs/export.rs): a non-empty
`traceEvents` array of complete ("ph": "X") slices with numeric ts/dur,
pid/tid lanes, and an `args` object carrying trace/span_id/parent.  With
`--expect PREFIX` (repeatable), at least one event name must start with
each prefix — how CI pins that solver, stage, daemon, and worker spans
actually made it into the export.

usage: check_trace.py TRACE.json [--expect PREFIX ...]
"""

import json
import sys


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main(argv):
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0 if argv else 2
    path, expect = argv[0], []
    rest = argv[1:]
    while rest:
        if rest[0] != "--expect" or len(rest) < 2:
            fail(f"unknown argument {rest[0]!r}")
        expect.append(rest[1])
        rest = rest[2:]

    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{path}: {e}")

    if not isinstance(doc, dict):
        fail("top level must be an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents must be a non-empty array")
    if doc.get("displayTimeUnit") != "ms":
        fail(f"displayTimeUnit must be 'ms', got {doc.get('displayTimeUnit')!r}")

    names = set()
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            fail(f"{where}: not an object")
        name = e.get("name")
        if not isinstance(name, str) or not name:
            fail(f"{where}: bad name {name!r}")
        names.add(name)
        if e.get("cat") != "ampq":
            fail(f"{where} ({name}): cat must be 'ampq'")
        if e.get("ph") != "X":
            fail(f"{where} ({name}): ph must be 'X' (complete slice)")
        for key in ("ts", "dur", "pid", "tid"):
            v = e.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                fail(f"{where} ({name}): bad {key} {v!r}")
        args = e.get("args")
        if not isinstance(args, dict):
            fail(f"{where} ({name}): args must be an object")
        if not isinstance(args.get("trace"), str) or not args["trace"]:
            fail(f"{where} ({name}): args.trace missing")
        for key in ("span_id", "parent"):
            if not isinstance(args.get(key), (int, float)):
                fail(f"{where} ({name}): args.{key} missing")
        for k, v in args.items():
            if k != "trace" and not isinstance(v, (int, float)):
                fail(f"{where} ({name}): counter {k}={v!r} is not numeric")

    for prefix in expect:
        if not any(n.startswith(prefix) for n in names):
            fail(f"no event name starts with {prefix!r}; saw: {sorted(names)}")

    print(f"check_trace: OK: {len(events)} event(s), {len(names)} distinct span name(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
